"""Deterministic replicated-data-plane simulations on the virtual clock.

Every simulation here drives the REAL routing table, control plane, and
autoscaler — only the execution units are timing stubs (one virtual-time
pod per replica) — through scripted virtual time, closing with the clock's
elapsed-real-time guard like test_slo_sim.py. Covers (ISSUE 9):

* replica-set routing: epoch bumps once per effective ordered-set change
  (the PR 3 no-op pins extended to multi-replica updates), spread policies
  (least-outstanding default, round-robin fallback), pick accounting;
* rho-driven scale-out recovering throughput on a hot function while a
  strict class's p95 stays in target, then trough scale-in back to one
  replica once the load stops — with every future resolving correctly;
* scale-in never dropping an in-flight request: the victim drains
  (DRAINING is set atomically with route removal, so no resolve can pick
  it) and retires only after its last request completes;
* the fuse-vs-replicate policy arm flipping on the spin-up-vs-merge-cost
  comparison, and replica count as fission pressure in decide_split;
* per-replica demand/billing attribution: spin-up canaries stamp no
  demand, each client request bills exactly one replica.
"""
import itertools
import threading
from concurrent.futures import wait

import pytest

from repro.core.autoscaler import Autoscaler
from repro.core.function import InstanceState
from repro.core.lifecycle import ControlPlane
from repro.core.policy import FusionPolicy
from repro.core.registry import (
    LeastOutstandingSpread,
    RoundRobinSpread,
    RoutingTable,
    make_spread,
)
from repro.scheduler import (
    AdaptiveConfig,
    RequestScheduler,
    SLOClass,
    VirtualClock,
)
from repro.scheduler.adaptive import SchedulerSignals

REAL_BUDGET_S = 10.0


def settle(clock, n=1):
    clock.wait_for_waiters(n, timeout=5.0)


def _pump(clock, dt, pred, max_iters=3000):
    """Advance virtual time on a fixed grid until ``pred()`` holds."""
    for _ in range(max_iters):
        if pred():
            return
        settle(clock)
        clock.advance(dt)
    raise AssertionError("simulation did not converge")


# --------------------------------------------------------- execution stub


_IDS = itertools.count()


class _SimReplica:
    """Timing stub of a FunctionInstance: the real lifecycle state machine
    and in-flight bracketing, with compute replaced by one virtual-time pod
    (requests serialize per replica, ``service_s`` of simulated time per
    batch) so replica parallelism is exactly the pod count."""

    def __init__(self, clock, members, service_s=0.008):
        self.clock = clock
        self.instance_id = f"sim-{next(_IDS)}"
        self.members = set(members)
        self.state = InstanceState.PROVISIONING
        self.service_s = service_s
        self._cv = threading.Condition()
        self._active = 0
        self._busy = False
        self.served = 0

    def mark_ready(self):
        self.state = InstanceState.READY

    def mark_serving(self):
        if self.state != InstanceState.RETIRED:
            self.state = InstanceState.SERVING

    def begin_drain(self):
        with self._cv:
            if self.state != InstanceState.RETIRED:
                self.state = InstanceState.DRAINING

    def begin_request(self):
        with self._cv:
            assert self.state != InstanceState.RETIRED, "request on retired unit"
            self._active += 1

    def end_request(self):
        with self._cv:
            self._active -= 1
            self._cv.notify_all()

    def outstanding(self):
        with self._cv:
            return self._active

    def occupy(self):
        """Hold this replica's pod for one batch service time."""
        with self._cv:
            while self._busy:
                self.clock.wait_on(self._cv, 0.5)
            self._busy = True
        self.clock.sleep(self.service_s)
        with self._cv:
            self._busy = False
            self.served += 1
            self._cv.notify_all()

    def retire(self, timeout=30.0):
        self.begin_drain()
        with self._cv:
            while self._active:
                self.clock.wait_on(self._cv, 0.5)
            self.state = InstanceState.RETIRED
        return 1000  # nominal freed bytes


class _SimPlatform:
    """Real RoutingTable + ControlPlane + RequestScheduler + Autoscaler on
    a virtual clock, dispatching into :class:`_SimReplica` pods."""

    def __init__(self, clock, *, service_s=0.008, spread=None, max_batch=4,
                 autoscale=None, idle_timeout_s=1.0):
        self.clock = clock
        self.service_s = service_s
        self.registry = RoutingTable(spread=spread)
        self.lifecycle = ControlPlane(self, self.registry, clock=clock)
        self.scheduler = RequestScheduler(
            self._dispatch, max_batch=max_batch, adaptive=True,
            adaptive_config=AdaptiveConfig(max_delay_s=0.016),
            idle_timeout_s=idle_timeout_s, be_shed_depth=10**6, clock=clock,
        )
        self.violations = []
        self.spawned = []
        self.autoscaler = None
        if autoscale is not None:
            self.autoscaler = Autoscaler(self, **autoscale)
            self.lifecycle.add_tick_hook(self.autoscaler.tick)

    def deploy(self, name):
        inst = _SimReplica(self.clock, {name}, self.service_s)
        inst.mark_ready()
        self.lifecycle.publish({name: inst}, kind="deploy", reason="deploy")
        return inst

    def _spawn_replica(self, name):
        primary = self.registry.get(name)
        if primary is None:
            return None
        replica = _SimReplica(self.clock, set(primary.members), self.service_s)
        replica.mark_ready()
        event = self.lifecycle.scale_out(
            replica, tuple(sorted(replica.members)),
            reason=f"replica of {primary.instance_id}",
        )
        if event is None:
            return None
        self.spawned.append(replica)
        return replica

    def request_replica(self, name, reason=""):
        if self.autoscaler is not None:
            self.autoscaler.request_scale_out(name, reason)

    def retire_instance(self, instance):
        return instance.retire()

    def _dispatch(self, name, args_list):
        instance, state = self.registry.resolve_entry(name)
        if state in (InstanceState.DRAINING, InstanceState.RETIRED):
            self.violations.append(f"resolved {instance.instance_id} in {state}")
        instance.begin_request()
        try:
            instance.occupy()
        finally:
            instance.end_request()
        return [a[0] for a in args_list]

    def shutdown(self):
        self.scheduler.shutdown()
        self.lifecycle.shutdown()


# ------------------------------------ epoch pins (publish bump semantics)


def test_version_bumps_once_per_effective_replica_set_change():
    """The PR 3 no-op pins, extended to multi-replica updates: ``version``
    is a routing epoch, so identical republishes of a replica SET, no-op
    add/removes, and empty updates must not mint new epochs."""
    clock = VirtualClock()
    rt = RoutingTable()
    a = _SimReplica(clock, {"f"})
    b = _SimReplica(clock, {"f"})
    v0 = rt.version
    rt.publish({})
    assert rt.version == v0  # empty publish: no epoch
    rt.register("f", a)
    rt.register("f", a)  # identical single route: no epoch
    assert rt.version == v0 + 1
    rt.publish({"f": (a, b)})  # replica set grew: ONE epoch
    assert rt.version == v0 + 2
    rt.publish({"f": (a, b)})  # identical ordered set: no epoch
    rt.publish({"f": [a, b]})  # list spelling of the same set: no epoch
    assert rt.version == v0 + 2
    assert rt.replicas("f") == (a, b)
    # add/remove replicas: one bump per effective change, none for no-ops
    assert rt.add_replicas(["f"], b) == ()  # already present
    assert rt.add_replicas(["ghost"], b) == ()  # unrouted name skipped
    assert rt.version == v0 + 2
    assert rt.remove_replicas(["f"], b) == ("f",)
    assert rt.version == v0 + 3
    assert rt.remove_replicas(["f"], b) == ()  # not a member anymore
    assert rt.remove_replicas(["f"], a) == ()  # keep_last: sole replica stays
    assert rt.version == v0 + 3
    assert rt.replicas("f") == (a,)
    # swap collapses a replica set to a single unit — but an identical
    # collapse is still a no-op
    rt.publish({"f": (a, b)})
    rt.swap(["f"], a)
    assert rt.version == v0 + 5
    rt.swap(["f"], a)
    rt.swap([], b)
    assert rt.version == v0 + 5
    # one real change among no-ops: ONE epoch
    rt.publish({"f": a, "g": b})
    assert rt.version == v0 + 6
    rt.unpublish(["f", "g"])
    assert rt.version == v0 + 7
    rt.unpublish(["f"])  # nothing routed: no epoch
    assert rt.version == v0 + 7


def test_publish_empty_sequence_unroutes_and_get_returns_primary():
    clock = VirtualClock()
    rt = RoutingTable()
    a = _SimReplica(clock, {"f"})
    b = _SimReplica(clock, {"f"})
    rt.publish({"f": (a, b)})
    assert rt.get("f") is a  # primary = first-published replica
    assert rt.replica_count("f") == 2
    assert rt.is_routed(b)
    displaced = rt.publish({"f": ()})
    assert displaced == {"f": (a, b)}
    assert rt.get("f") is None and not rt.is_routed(a)
    with pytest.raises(Exception):
        rt.resolve("f")


# -------------------------------------------------------- spread policies


def test_round_robin_spread_cycles_in_publish_order():
    clock = VirtualClock()
    rt = RoutingTable(spread="round-robin")
    assert rt.spread_name == "round-robin"
    a, b, c = (_SimReplica(clock, {"f"}) for _ in range(3))
    rt.publish({"f": (a, b, c)})
    picked = [rt.resolve("f") for _ in range(6)]
    assert picked == [a, b, c, a, b, c]
    summary = rt.replica_summary()["f"]
    assert summary["replicas"] == [a.instance_id, b.instance_id, c.instance_id]
    assert summary["picks"] == {r.instance_id: 2 for r in (a, b, c)}


def test_least_outstanding_spread_prefers_idle_replica_and_rotates_ties():
    clock = VirtualClock()
    rt = RoutingTable()  # least-outstanding is the default
    assert rt.spread_name == "least-outstanding"
    a, b = _SimReplica(clock, {"f"}), _SimReplica(clock, {"f"})
    rt.publish({"f": (a, b)})
    a.begin_request()  # a is busy: every pick must land on b
    assert all(rt.resolve("f") is b for _ in range(4))
    a.end_request()
    picked = {rt.resolve("f") for _ in range(2)}
    assert picked == {a, b}, "ties must rotate, not pin one replica"
    # resolve_entry surfaces the picked replica's state atomically
    inst, state = rt.resolve_entry("f")
    assert state == InstanceState.PROVISIONING  # stub default; never DRAINING


def test_make_spread_resolves_names_instances_and_rejects_unknown():
    assert isinstance(make_spread(None), LeastOutstandingSpread)
    assert isinstance(make_spread("round-robin"), RoundRobinSpread)
    rr = RoundRobinSpread()
    assert make_spread(rr) is rr
    with pytest.raises(ValueError, match="unknown spread"):
        make_spread("po2")


# ------------------------------------------------------------- autoscaler


def test_autoscaler_rejects_inverted_replica_bounds():
    clock = VirtualClock()
    plat = _SimPlatform(clock)
    try:
        with pytest.raises(ValueError):
            Autoscaler(plat, max_replicas=1, min_replicas=2)
    finally:
        plat.shutdown()


def test_replicate_hint_spawns_replica_up_to_the_cap():
    """The fusion policy's replicate arm lands as a reconciler-tick hint:
    the spin-up happens on the control-plane thread, respects max_replicas,
    and records a scale-out event."""
    clock = VirtualClock()
    plat = _SimPlatform(clock, autoscale=dict(
        rho_high=99.0, sustain=99, max_replicas=2, cooldown_s=0.0,
        eval_interval_s=0.01,
    ))
    try:
        plat.deploy("svc")
        plat.request_replica("svc", reason="saturated callee: replicate")
        _pump(clock, 0.01, lambda: plat.registry.replica_count("svc") == 2)
        plat.request_replica("svc", reason="again")  # over the cap: no-op
        for _ in range(10):
            settle(clock)
            clock.advance(0.01)
        assert plat.registry.replica_count("svc") == 2
        events = plat.autoscaler.stats()["events"]
        assert [e["kind"] for e in events] == ["scale-out"]
        assert "replicate" in events[0]["reason"]
        # the epoch log recorded it as a scale-out transition
        kinds = [e.kind for e in plat.lifecycle.events]
        assert kinds == ["deploy", "scale-out"]
        clock.assert_elapsed_real_below(REAL_BUDGET_S)
    finally:
        plat.shutdown()


# ------------------------------- the tentpole sim: scale out, then back in


def _run_hot_function_trace(plat, clock, rounds=40, per_lane=2):
    """Open-loop skewed load: ``per_lane`` requests per virtual 8ms round on
    each of 4 shape-distinct lanes of "hot", plus a strict gold trickle.
    Returns (best-effort futures, gold futures, makespan seconds)."""
    gold = SLOClass("gold", 250.0)
    futs, gold_futs = [], []
    t0 = clock.now()
    for r in range(rounds):
        for lane in range(4):
            for k in range(per_lane):
                futs.append(plat.scheduler.submit(
                    "hot", (r * 100 + lane * 10 + k, (0,) * (lane + 1))))
        if r % 4 == 0:
            gold_futs.append(plat.scheduler.submit(
                "hot", (9000 + r, (0,) * 5), slo=gold))
        target = t0 + (r + 1) * 0.008
        _pump(clock, 0.002, lambda: clock.now() >= target - 1e-9)
    _pump(clock, 0.002,
          lambda: all(f.done() for f in futs + gold_futs), max_iters=5000)
    return futs, gold_futs, clock.now() - t0


def test_sim_scale_out_recovers_throughput_then_trough_scale_in():
    """The replicated data plane end to end, all in virtual time: a hot
    function under 2x its single-unit capacity gains replicas from the
    rho-driven autoscaler (makespan shrinks vs the single-instance
    baseline), the strict class stays in target, every future resolves with
    its own payload, no resolve ever lands on a draining replica — and once
    the load stops, trough scale-in drains back to one replica without
    dropping anything."""
    # baseline: same trace, no autoscaler, one replica throughout
    clock_b = VirtualClock()
    base = _SimPlatform(clock_b)
    try:
        base.deploy("hot")
        futs_b, gold_b, makespan_base = _run_hot_function_trace(base, clock_b)
        assert not base.violations, base.violations[:3]
        assert base.registry.replica_count("hot") == 1
        for f in futs_b + gold_b:
            assert f.exception() is None
        clock_b.assert_elapsed_real_below(REAL_BUDGET_S)
    finally:
        base.shutdown()

    clock = VirtualClock()
    plat = _SimPlatform(clock, autoscale=dict(
        rho_high=1.0, rho_low=0.2, sustain=2, max_replicas=3,
        cooldown_s=0.05, eval_interval_s=0.02,
    ))
    try:
        plat.deploy("hot")
        futs, gold_futs, makespan = _run_hot_function_trace(plat, clock)
        assert not plat.violations, plat.violations[:3]
        # conservation: every future resolved, each with its own payload
        done, not_done = wait(futs + gold_futs, timeout=5)
        assert not not_done
        for f in futs + gold_futs:
            assert f.exception() is None
        payloads = [f.result() for f in futs]
        assert payloads == [r * 100 + lane * 10 + k
                            for r in range(40) for lane in range(4)
                            for k in range(2)]
        # the autoscaler actually scaled out to the cap...
        assert plat.registry.replica_count("hot") == 3
        out_events = [e for e in plat.autoscaler.stats()["events"]
                      if e["kind"] == "scale-out"]
        assert len(out_events) == 2 and all("rho" in e["reason"] for e in out_events)
        # ...every replica took real work through the spread...
        assert all(rep.served > 0 for rep in plat.spawned)
        picks = plat.registry.replica_summary()["hot"]["picks"]
        assert len(picks) == 3 and all(n > 0 for n in picks.values())
        # ...throughput recovered vs the single-instance baseline...
        assert makespan <= 0.75 * makespan_base, (makespan, makespan_base)
        # ...and the strict class stayed in target throughout the overload
        gold_stats = plat.scheduler.class_stats()["gold"]
        assert gold_stats["met"] is True, gold_stats

        # load stops -> lanes idle out -> rho reads 0 -> trough scale-in
        # drains back to one replica, newest first, nothing dropped
        _pump(clock, 0.05, lambda: plat.registry.replica_count("hot") == 1,
              max_iters=300)
        assert not plat.violations, plat.violations[:3]
        in_events = [e for e in plat.autoscaler.stats()["events"]
                     if e["kind"] == "scale-in"]
        assert len(in_events) == 2
        assert all(r.state == InstanceState.RETIRED for r in plat.spawned)
        primary = plat.registry.get("hot")
        assert primary.state == InstanceState.SERVING
        assert primary not in plat.spawned, "the primary replica must persist"
        clock.assert_elapsed_real_below(REAL_BUDGET_S)
    finally:
        plat.shutdown()


def test_sim_scale_in_never_drops_an_in_flight_request():
    """Scale-in's drain path: route removal + DRAINING happen atomically
    (no resolve can pick the victim), but retirement waits for the victim's
    in-flight request to finish — the request completes normally."""
    clock = VirtualClock()
    plat = _SimPlatform(clock)
    try:
        plat.deploy("hot")
        victim = plat._spawn_replica("hot")
        assert victim is not None and plat.registry.replica_count("hot") == 2
        finished = []

        def in_flight():
            victim.begin_request()
            try:
                clock.sleep(0.05)
            finally:
                victim.end_request()
            finished.append(clock.now())

        worker = threading.Thread(target=in_flight, daemon=True)
        worker.start()
        settle(clock)  # the request is mid-service, parked on the clock
        out = {}
        drainer = threading.Thread(
            target=lambda: out.update(
                event=plat.lifecycle.scale_in(victim, reason="trough")),
            daemon=True)
        drainer.start()
        settle(clock, 2)  # drainer blocked in retire, worker still serving
        assert victim.state == InstanceState.DRAINING
        assert not finished, "scale-in must not cancel the in-flight request"
        # the route flip already happened: only the primary resolves
        assert plat.registry.replicas("hot") == (plat.registry.get("hot"),)
        for _ in range(8):
            inst, state = plat.registry.resolve_entry("hot")
            assert inst is not victim and state == InstanceState.SERVING
        clock.advance(0.05)  # the request completes -> drain finishes
        worker.join(timeout=5)
        drainer.join(timeout=5)
        assert finished and victim.state == InstanceState.RETIRED
        event = out["event"]
        assert event.kind == "scale-in" and event.names == ("hot",)
        assert event.retired == (victim.instance_id,)
        # a sole replica refuses to scale in (that would unroute the name)
        assert plat.lifecycle.scale_in(plat.registry.get("hot")) is None
        assert plat.registry.get("hot").state == InstanceState.SERVING
        clock.assert_elapsed_real_below(REAL_BUDGET_S)
    finally:
        plat.shutdown()


# ----------------------------------------------- fuse-vs-replicate policy


class _EdgeStats:
    def __init__(self, sync_count=50, mean_wait_s=0.05):
        self.sync_count = sync_count
        self.mean_wait_s = mean_wait_s
        self.p95_wait_s = mean_wait_s


SATURATED = SchedulerSignals(queue_depth=4, mean_occupancy=1.0, p95_ms=0.0)


def test_policy_flips_replicate_when_spinup_beats_merge_cost():
    pol = FusionPolicy(merge_cost_s=2.0)
    # warm replica (50ms) vs a 2s merge on a saturated callee: replicate
    d = pol.decide("A", "B", _EdgeStats(), "t", "t", SATURATED,
                   replica_spinup_s=0.05, callee_replicas=1)
    assert d.replicate and not d.fuse
    assert "replica" in d.reason and "beats merge" in d.reason
    # spin-up slower than the merge itself: back to the penalized-merge arm
    # (saving 0.05 x 500 = 25s >= 2 x 4 = 8s, so the merge still wins)
    d = pol.decide("A", "B", _EdgeStats(), "t", "t", SATURATED,
                   replica_spinup_s=5.0, callee_replicas=1)
    assert not d.replicate and d.fuse
    assert "saturated" in d.reason


def test_policy_replicate_arm_respects_cap_estimate_and_kill_switch():
    base = dict(replica_spinup_s=0.05, callee_replicas=1)
    # callee already at the replica-hint cap: capacity is not the fix
    d = FusionPolicy(merge_cost_s=2.0, max_replica_hint=2).decide(
        "A", "B", _EdgeStats(), "t", "t", SATURATED,
        replica_spinup_s=0.05, callee_replicas=2)
    assert not d.replicate and d.fuse
    # no spin-up estimate yet (no replica ever spun up): never replicate
    d = FusionPolicy(merge_cost_s=2.0).decide(
        "A", "B", _EdgeStats(), "t", "t", SATURATED,
        replica_spinup_s=None, callee_replicas=1)
    assert not d.replicate
    # kill switch
    d = FusionPolicy(merge_cost_s=2.0, replicate_enabled=False).decide(
        "A", "B", _EdgeStats(), "t", "t", SATURATED, **base)
    assert not d.replicate
    # an UNsaturated callee never replicates — capacity is not the problem
    calm = SchedulerSignals(queue_depth=0, mean_occupancy=0.1)
    d = FusionPolicy(merge_cost_s=2.0).decide(
        "A", "B", _EdgeStats(), "t", "t", calm, **base)
    assert not d.replicate and d.fuse


def test_decide_split_replica_count_halves_the_sustain_floor():
    members = frozenset({"a", "b"})
    sat = SchedulerSignals(queue_depth=4, mean_occupancy=1.0)
    # unreplicated group: the full split_sustain=3 evaluations are required
    pol = FusionPolicy()
    for _ in range(2):
        assert not pol.decide_split(members, signals=sat, age_s=5.0).split
    assert pol.decide_split(members, signals=sat, age_s=5.0).split
    # a replicated group is fission pressure: the floor halves to 1
    pol2 = FusionPolicy()
    d = pol2.decide_split(members, signals=sat, age_s=5.0, replica_count=3)
    assert d.split and "replica pressure" in d.reason
    assert d.partition == (frozenset({"a"}), frozenset({"b"}))


# ------------------------------------- demand + billing attribution (real)


def test_spawn_replica_stamps_no_demand_and_bills_each_request_once():
    """note_demand fires once per client request at the entry points; the
    spin-up canary goes through direct execute, so replica provisioning
    must leave the demand rate untouched — and by_instance's buckets must
    sum to exactly the client request count across the replica set."""
    pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.core import FunctionSpec, TinyJaxBackend

    clock = VirtualClock()
    p = TinyJaxBackend(FusionPolicy(enabled=False), clock=clock)
    try:
        p.deploy(FunctionSpec("f", lambda ctx, params, x: x * 2 + 1, None))
        for i in range(6):
            p.invoke("f", jnp.float32(i))
        rate_before = p.handler.recent_rate("f")
        assert rate_before > 0.0
        replica = p._spawn_replica("f")
        assert replica is not None
        # the canary warm-up billed nothing and stamped no demand (the
        # virtual clock froze time, so the windowed rate is exact)
        assert p.handler.recent_rate("f") == rate_before
        assert p.meter.summary()["by_function"]["f"]["calls"] == 6
        prov = [r for r in p.meter.provisioning if r.kind == "scale-out"]
        assert len(prov) == 1 and prov[0].billed
        assert prov[0].warm, "replica spin-up must restore, not rebuild"
        assert p.replica_spinup_estimate() is not None
        # 6 more requests spread over both replicas: 12 billed calls total,
        # each request in exactly one replica's bucket
        for i in range(6):
            assert float(p.invoke("f", jnp.float32(i))) == i * 2 + 1
        by_inst = p.meter.by_instance()
        stats = p.stats()["replicas"]
        info = stats["functions"]["f"]
        assert len(info["replicas"]) == 2
        assert sum(d["calls"] for d in by_inst.values()) == 12
        assert sum(info["picks"].values()) == 12
        assert all(n >= 2 for n in info["picks"].values()), (
            "least-outstanding ties must rotate across idle replicas")
        assert set(info["billing"]) <= set(info["replicas"])
        assert stats["spread"] == "least-outstanding"
        assert p.meter.summary()["by_function"]["f"]["calls"] == 12
        clock.assert_elapsed_real_below(REAL_BUDGET_S)
    finally:
        p.shutdown()
