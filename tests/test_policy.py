"""Property tests for the fusion policy + union-find groups.

Hand-rolled seeded property loops (no optional `hypothesis` dependency —
tier-1 must collect on a bare jax+pytest environment). Each loop draws many
random cases from a fixed-seed RNG and checks the same invariants the
original hypothesis strategies expressed.
"""
import random

from repro.core.handler import EdgeStats
from repro.core.policy import FusionPolicy, UnionFind

NAMES = [f"f{i}" for i in range(8)]


def test_union_find_partition_invariants():
    rng = random.Random(0xC0FFEE)
    for _ in range(60):
        pairs = [(rng.choice(NAMES), rng.choice(NAMES)) for _ in range(rng.randint(0, 30))]
        uf = UnionFind()
        for a, b in pairs:
            uf.union(a, b)
        seen = {x for ab in pairs for x in ab}
        # reflexive + symmetric + transitive: groups partition the elements
        for x in seen:
            gx = uf.group(x)
            assert x in gx
            for y in gx:
                assert uf.group(y) == gx
        # union implies same group
        for a, b in pairs:
            assert uf.find(a) == uf.find(b)


def test_policy_decision_consistency():
    rng = random.Random(1234)
    for _ in range(80):
        sync = rng.randint(0, 10)
        wait_ms = rng.uniform(0.0, 50.0)
        min_obs = rng.randint(1, 5)
        horizon = rng.randint(1, 1000)
        cost = rng.uniform(0.0, 5.0)
        policy = FusionPolicy(min_observations=min_obs, amortization_horizon=horizon, merge_cost_s=cost)
        stats = EdgeStats(sync_count=sync, total_wait_s=sync * wait_ms / 1e3)
        d = policy.decide("a", "b", stats, "t", "t")
        if d.fuse:
            assert sync >= min_obs
            assert stats.mean_wait_s * horizon >= cost
            assert {"a", "b"} <= set(d.group)
        if sync < min_obs:
            assert not d.fuse


def test_policy_cross_trust_never_fuses():
    policy = FusionPolicy(min_observations=0, merge_cost_s=0.0)
    stats = EdgeStats(sync_count=100, total_wait_s=10.0)
    assert not policy.decide("a", "b", stats, "t1", "t2").fuse


def test_policy_commit_grows_groups_transitively():
    policy = FusionPolicy()
    policy.commit("a", "b")
    policy.commit("b", "c")
    assert policy.groups.group("a") == frozenset({"a", "b", "c"})
    stats = EdgeStats(sync_count=100, total_wait_s=10.0)
    # an edge within the committed group never re-fuses
    assert not policy.decide("a", "c", stats, "t", "t").fuse


def test_policy_disabled():
    policy = FusionPolicy(enabled=False)
    stats = EdgeStats(sync_count=100, total_wait_s=10.0)
    assert not policy.decide("a", "b", stats, "t", "t").fuse


def test_merge_cost_feedback_moves_estimate():
    policy = FusionPolicy(merge_cost_s=2.0)
    policy.feedback_merge_cost(0.0)
    assert policy.merge_cost_s == 1.0
