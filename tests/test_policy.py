"""Property tests for the fusion policy + union-find groups.

Hand-rolled seeded property loops (no optional `hypothesis` dependency —
tier-1 must collect on a bare jax+pytest environment). Each loop draws many
random cases from a fixed-seed RNG and checks the same invariants the
original hypothesis strategies expressed.
"""
import random
import threading

from repro.core.handler import EdgeStats
from repro.core.policy import FusionPolicy, UnionFind
from repro.scheduler import SchedulerSignals

NAMES = [f"f{i}" for i in range(8)]


def test_union_find_partition_invariants():
    rng = random.Random(0xC0FFEE)
    for _ in range(60):
        pairs = [(rng.choice(NAMES), rng.choice(NAMES)) for _ in range(rng.randint(0, 30))]
        uf = UnionFind()
        for a, b in pairs:
            uf.union(a, b)
        seen = {x for ab in pairs for x in ab}
        # reflexive + symmetric + transitive: groups partition the elements
        for x in seen:
            gx = uf.group(x)
            assert x in gx
            for y in gx:
                assert uf.group(y) == gx
        # union implies same group
        for a, b in pairs:
            assert uf.find(a) == uf.find(b)


def test_policy_decision_consistency():
    rng = random.Random(1234)
    for _ in range(80):
        sync = rng.randint(0, 10)
        wait_ms = rng.uniform(0.0, 50.0)
        min_obs = rng.randint(1, 5)
        horizon = rng.randint(1, 1000)
        cost = rng.uniform(0.0, 5.0)
        policy = FusionPolicy(min_observations=min_obs, amortization_horizon=horizon, merge_cost_s=cost)
        stats = EdgeStats(sync_count=sync, total_wait_s=sync * wait_ms / 1e3)
        d = policy.decide("a", "b", stats, "t", "t")
        if d.fuse:
            assert sync >= min_obs
            assert stats.mean_wait_s * horizon >= cost
            assert {"a", "b"} <= set(d.group)
        if sync < min_obs:
            assert not d.fuse


def test_policy_cross_trust_never_fuses():
    policy = FusionPolicy(min_observations=0, merge_cost_s=0.0)
    stats = EdgeStats(sync_count=100, total_wait_s=10.0)
    assert not policy.decide("a", "b", stats, "t1", "t2").fuse


def test_policy_commit_grows_groups_transitively():
    policy = FusionPolicy()
    policy.commit("a", "b")
    policy.commit("b", "c")
    assert policy.groups.group("a") == frozenset({"a", "b", "c"})
    stats = EdgeStats(sync_count=100, total_wait_s=10.0)
    # an edge within the committed group never re-fuses
    assert not policy.decide("a", "c", stats, "t", "t").fuse


def test_policy_disabled():
    policy = FusionPolicy(enabled=False)
    stats = EdgeStats(sync_count=100, total_wait_s=10.0)
    assert not policy.decide("a", "b", stats, "t", "t").fuse


def test_merge_cost_feedback_moves_estimate():
    policy = FusionPolicy(merge_cost_s=2.0)
    policy.feedback_merge_cost(0.0)
    assert policy.merge_cost_s == 1.0


class _CountingLock:
    """threading.Lock wrapper that counts acquisitions."""

    def __init__(self):
        self.inner = threading.Lock()
        self.acquisitions = 0

    def __enter__(self):
        self.inner.acquire()
        self.acquisitions += 1
        return self

    def __exit__(self, *exc):
        self.inner.release()
        return False


def test_feedback_merge_cost_takes_the_decide_lock():
    """Regression (PR 2): feedback_merge_cost updated merge_cost_s WITHOUT
    self._lock while decide() read it under the lock — a racing async-build
    Merger thread could publish a half-applied EWMA. The write must go
    through the same lock decide uses."""
    policy = FusionPolicy(merge_cost_s=2.0)
    lock = _CountingLock()
    policy._lock = lock
    policy.feedback_merge_cost(1.0)
    assert lock.acquisitions == 1, "feedback_merge_cost must hold the policy lock"
    assert policy.merge_cost_s == 1.5


def test_concurrent_feedback_and_decide_keep_estimate_consistent():
    """Hammer feedback_merge_cost from several threads while decide() spins.
    With every feedback feeding the same value v, the EWMA fixed point is v:
    any deviation means a torn read-modify-write."""
    policy = FusionPolicy(min_observations=1, merge_cost_s=0.5, amortization_horizon=100)
    stats = EdgeStats(sync_count=10, total_wait_s=1.0)
    stop = threading.Event()
    errors = []

    def feeder():
        while not stop.is_set():
            policy.feedback_merge_cost(0.5)

    def decider():
        while not stop.is_set():
            d = policy.decide("a", "b", stats, "t", "t")
            if not d.fuse:  # saving 10s >> cost 0.5s: must always fuse
                errors.append(d.reason)

    threads = [threading.Thread(target=feeder) for _ in range(3)]
    threads += [threading.Thread(target=decider) for _ in range(2)]
    for t in threads:
        t.start()
    import time

    time.sleep(0.3)  # provlint: ok — contention window is the scenario
    stop.set()
    for t in threads:
        t.join(timeout=5)
    assert not errors, errors[:3]
    assert policy.merge_cost_s == 0.5


# ------------------------------------------------------- scheduler signals


def _hot_edge(wait_s=0.01, count=100):
    return EdgeStats(sync_count=count, total_wait_s=wait_s * count)


def test_saturated_chain_deprioritizes_merge():
    """Full batches + queued backlog: micro-batching is already absorbing the
    load, so the merge stall must clear a (much) higher amortization bar."""
    policy = FusionPolicy(min_observations=1, merge_cost_s=2.0, amortization_horizon=500,
                          saturation_penalty=4.0)
    stats = _hot_edge(wait_s=0.01)  # saving 5s: beats 2.0, not 8.0
    assert policy.decide("a", "b", stats, "t", "t").fuse
    saturated = SchedulerSignals(queue_depth=8, mean_occupancy=0.95, p95_ms=5.0)
    d = policy.decide("a", "b", stats, "t", "t", signals=saturated)
    assert not d.fuse and "saturated" in d.reason
    # clearly amortizable even at the penalized bar: still fuses
    big = _hot_edge(wait_s=0.1)  # saving 50s > 8.0
    assert policy.decide("a", "b", big, "t", "t", signals=saturated).fuse


def test_measured_merge_stall_displaces_static_saturation_penalty():
    """The same saturated edge decides DIFFERENTLY once measured costs
    exist: the static 4x penalty vetoes the merge (required 8s > saving
    5s), but an attached EdgeCostModel holding a measured ~50ms build
    stall prices the saturation at stall x queue-depth instead — required
    ~2.1s < 5s, so the merge goes through."""
    from repro.obs import EdgeCostModel

    policy = FusionPolicy(min_observations=1, merge_cost_s=2.0,
                          amortization_horizon=500, saturation_penalty=4.0)
    stats = _hot_edge(wait_s=0.01)  # projected saving 5s
    saturated = SchedulerSignals(queue_depth=2, mean_occupancy=0.95, p95_ms=5.0)
    d = policy.decide("a", "b", stats, "t", "t", signals=saturated)
    assert not d.fuse and "saturated" in d.reason
    cm = EdgeCostModel()
    cm.observe_merge_stall(0.05, queue_depth=2)
    policy.cost_model = cm
    d = policy.decide("a", "b", stats, "t", "t", signals=saturated)
    assert d.fuse and "measured stall" in d.reason


def test_measured_edge_ewma_displaces_alltime_mean_wait():
    """An edge whose all-time mean says 'fuse' but whose RECENT measured
    sync waits collapsed (traffic pattern changed) must not fuse: the
    cost model's EWMA replaces stats.mean_wait_s in the projected saving."""
    from repro.obs import EdgeCostModel

    policy = FusionPolicy(min_observations=1, merge_cost_s=2.0,
                          amortization_horizon=500)
    stats = _hot_edge(wait_s=0.01)  # all-time mean saving 5s > 2s: fuses
    assert policy.decide("a", "b", stats, "t", "t").fuse
    cm = EdgeCostModel()
    for _ in range(20):  # measured waits now ~1ms: saving 0.5s < 2s
        cm.observe_sync_edge("a", "b", 0.001)
    policy.cost_model = cm
    d = policy.decide("a", "b", stats, "t", "t")
    assert not d.fuse and "not amortizable" in d.reason
    # an edge the model has never seen still prices from the static mean
    assert policy.decide("x", "y", stats, "t", "t").fuse


def test_cold_chain_with_long_waits_promotes_merge():
    """Low occupancy + long tail waits: blocking dominates, fusion removes it
    — the policy halves the observation floor and discounts the cost."""
    policy = FusionPolicy(min_observations=4, merge_cost_s=2.0, amortization_horizon=500,
                          promote_wait_s=0.05, promote_discount=0.5)
    # 2 observations of 100ms waits: below the floor without signals
    stats = EdgeStats(sync_count=2, total_wait_s=0.2)
    assert not policy.decide("a", "b", stats, "t", "t").fuse
    cold = SchedulerSignals(queue_depth=0, mean_occupancy=0.1, p95_ms=120.0)
    d = policy.decide("a", "b", stats, "t", "t", signals=cold)
    assert d.fuse and "promoted" in d.reason
    # fast cold chains (short waits) are NOT promoted
    quick = EdgeStats(sync_count=2, total_wait_s=0.002)
    idle = SchedulerSignals(queue_depth=0, mean_occupancy=0.1, p95_ms=1.0)
    assert not policy.decide("a", "b", quick, "t", "t", signals=idle).fuse


def test_exec_slow_chain_with_tiny_sync_waits_is_not_promoted():
    """A chain whose p95 is dominated by slow COMPUTE (not blocking) must not
    get the promote discount — fusion removes sync waits, not model math.
    The trigger is the edge's own sync-wait tail, gated by its share of the
    end-to-end p95."""
    policy = FusionPolicy(min_observations=4, merge_cost_s=2.0, amortization_horizon=500,
                          promote_wait_s=0.05, promote_discount=0.5)
    # sync waits are a tiny slice of a 300ms end-to-end p95
    stats = EdgeStats(sync_count=2, total_wait_s=0.004)
    slow_exec = SchedulerSignals(queue_depth=0, mean_occupancy=0.1, p95_ms=300.0)
    assert not policy.decide("a", "b", stats, "t", "t", signals=slow_exec).fuse
    # long sync waits that are ALSO a tiny share of p95: blocked by the gate
    waits = EdgeStats(sync_count=2, total_wait_s=0.12)  # 60ms mean waits
    huge_p95 = SchedulerSignals(queue_depth=0, mean_occupancy=0.1, p95_ms=2000.0)
    d = policy.decide("a", "b", waits, "t", "t", signals=huge_p95)
    assert not d.fuse and "promoted" not in d.reason


def test_edge_stats_p95_wait_tracks_tail_not_mean():
    st = EdgeStats()
    for w in [0.001] * 18 + [0.5]:  # 19 samples: rank ceil(0.95*19)=19 = the outlier
        st.sync_count += 1
        st.total_wait_s += w
        st.recent_waits.append(w)
    assert st.mean_wait_s < 0.03
    assert st.p95_wait_s == 0.5
    st2 = EdgeStats(sync_count=3, total_wait_s=0.3)
    assert st2.p95_wait_s == st2.mean_wait_s  # no history: falls back to mean


def test_violated_slo_class_promotes_fixing_merge():
    """A strict class over target whose violation the merge's removed
    sync-wait would cure: half the observation floor, discounted cost —
    even when the generic promote gates (wait share of p95) wouldn't fire."""
    policy = FusionPolicy(min_observations=4, merge_cost_s=2.0, amortization_horizon=500,
                          promote_wait_s=10.0, promote_discount=0.5)
    # 2 observations of 30ms waits: below the floor without signals, and far
    # below promote_wait_s so only the SLO path can promote
    stats = EdgeStats(sync_count=2, total_wait_s=0.06)
    assert not policy.decide("a", "b", stats, "t", "t").fuse
    # gold at 60ms vs a 40ms target: removing ~30ms of wait un-violates it
    fixable = SchedulerSignals(queue_depth=0, mean_occupancy=0.1, p95_ms=60.0,
                               class_p95_ms=(("gold", 60.0, 40.0),))
    d = policy.decide("a", "b", stats, "t", "t", signals=fixable)
    assert d.fuse and "gold" in d.reason
    # gold at 200ms vs 40ms: the merge cannot cure it -> no SLO promote
    hopeless = SchedulerSignals(queue_depth=0, mean_occupancy=0.1, p95_ms=200.0,
                                class_p95_ms=(("gold", 200.0, 40.0),))
    d = policy.decide("a", "b", stats, "t", "t", signals=hopeless)
    assert not d.fuse and "gold" not in d.reason
    # a class meeting its target never promotes
    healthy = SchedulerSignals(queue_depth=0, mean_occupancy=0.1, p95_ms=30.0,
                               class_p95_ms=(("gold", 30.0, 40.0),))
    assert not policy.decide("a", "b", stats, "t", "t", signals=healthy).fuse


def test_sustained_slo_violation_is_a_fission_regret_signal():
    """A strict class over target on a fused group for split_sustain
    consecutive evaluations orders a split into singletons; an oscillating
    violation never does (streak resets, same discipline as saturation)."""
    policy = FusionPolicy(min_group_age_s=0.0, split_sustain=3)
    members = frozenset({"A", "B"})
    bad = SchedulerSignals(queue_depth=0, mean_occupancy=0.2, p95_ms=90.0,
                           class_p95_ms=(("gold", 90.0, 40.0),))
    ok = SchedulerSignals(queue_depth=0, mean_occupancy=0.2, p95_ms=20.0,
                          class_p95_ms=(("gold", 20.0, 40.0),))
    # oscillating: the streak resets before reaching split_sustain
    for _ in range(4):
        assert not policy.decide_split(members, signals=bad, age_s=1.0).split
        assert not policy.decide_split(members, signals=bad, age_s=1.0).split
        assert not policy.decide_split(members, signals=ok, age_s=1.0).split
    # sustained: splits on the 3rd consecutive violated evaluation
    assert not policy.decide_split(members, signals=bad, age_s=1.0).split
    assert not policy.decide_split(members, signals=bad, age_s=1.0).split
    d = policy.decide_split(members, signals=bad, age_s=1.0)
    assert d.split and "SLO" in d.reason and "gold" in d.reason
    assert set().union(*d.partition) == members


def test_worst_violation_picks_largest_overshoot():
    sig = SchedulerSignals(class_p95_ms=(("a", 50.0, 40.0), ("b", 90.0, 30.0),
                                         ("c", 10.0, 40.0)))
    assert sig.worst_violation() == ("b", 90.0, 30.0)
    assert SchedulerSignals().worst_violation() is None
    met = SchedulerSignals(class_p95_ms=(("a", 10.0, 40.0),))
    assert met.worst_violation() is None


def test_zero_target_class_is_never_a_violation():
    """Regression: IMMEDIATE (the PRIORITY_HIGH shim, target 0) promises
    zero ADMISSION delay — end-to-end p95 always includes service time, so
    reading it as violated kept every fused group in a permanent fission
    streak (split -> backoff -> re-merge -> split, forever)."""
    import math

    sig = SchedulerSignals(class_p95_ms=(("immediate", 5.8, 0.0),))
    assert sig.worst_violation() is None
    policy = FusionPolicy(min_group_age_s=0.0, split_sustain=1)
    d = policy.decide_split(frozenset({"A", "B"}), signals=sig, age_s=1.0)
    assert not d.split, d.reason
    # infinite targets (best-effort) are equally inert
    be = SchedulerSignals(class_p95_ms=(("be", 500.0, math.inf),))
    assert be.worst_violation() is None
