"""Whole-system integration: the quickstart path — deploy a composed app,
serve traffic, watch the platform converge, verify nothing regressed."""
import jax.numpy as jnp
import numpy as np

from benchmarks.apps import deploy_iot, make_request
from repro.core import FusionPolicy, TinyJaxBackend


def test_iot_app_end_to_end_with_fusion():
    platform = TinyJaxBackend(FusionPolicy(min_observations=3, merge_cost_s=0.0))
    try:
        entry = deploy_iot(platform)
        ref_out = None
        for i in range(10):
            out = platform.invoke(entry, make_request(0))
            if ref_out is None:
                ref_out = np.asarray(out)
            else:
                np.testing.assert_allclose(np.asarray(out), ref_out, rtol=2e-4, atol=1e-5)
        stats = platform.stats()
        healthy = [m for m in stats["merges"] if m["healthy"]]
        assert healthy, "IOT sync edges must fuse"
        # the sync group analyze+temperature+airquality+traffic converges
        final_members = set(healthy[-1]["members"])
        assert "iot/analyze" in final_members and len(final_members) >= 3
        # async store stays isolated
        assert platform.registry.resolve("iot/store").members.keys() == {"iot/store"}
        assert stats["billing"]["total_gb_s"] > 0
    finally:
        platform.shutdown()
