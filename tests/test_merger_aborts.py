"""Merger abort paths: an unverifiable or unhealthy fused unit must NEVER
take traffic, and its provisioned resources must be torn down."""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FunctionSpec, FusionPolicy, OrchestratedBackend, TinyJaxBackend
from repro.core.function import FunctionInstance
from repro.core.handler import EdgeStats

BACKENDS = [TinyJaxBackend, OrchestratedBackend]


def deploy_pair(platform, w):
    def fn_b(ctx, params, x):
        return jnp.tanh(x @ params)

    def fn_a(ctx, params, x):
        return ctx.call("B", x @ params)

    platform.deploy(FunctionSpec("A", fn_a, w))
    platform.deploy(FunctionSpec("B", fn_b, w))


@pytest.mark.parametrize("backend_cls", BACKENDS)
def test_no_canary_abort_keeps_routing_and_detaches_unit(backend_cls):
    p = backend_cls(FusionPolicy(min_observations=1, merge_cost_s=0.0))
    try:
        deploy_pair(p, jnp.eye(8) * 0.5)
        before = {n: id(p.registry.resolve(n)) for n in ("A", "B")}
        # a hot edge exists but NO canary traffic was ever captured
        p.handler.edges[("A", "B")] = EdgeStats(sync_count=5, total_wait_s=1.0)
        p.merger.submit("A", "B")
        events = p.merger.merge_log
        assert events and not events[-1].healthy
        assert events[-1].reason == "no canary traffic captured"
        assert events[-1].checked_members == ()
        assert {n: id(p.registry.resolve(n)) for n in ("A", "B")} == before
        if backend_cls is OrchestratedBackend:
            # the never-promoted unit's pod must be gone
            live_members = {tuple(sorted(w.instance.members)) for w in p._workers.values()}
            assert ("A", "B") not in live_members
    finally:
        p.shutdown()


@pytest.mark.parametrize("backend_cls", BACKENDS)
def test_health_check_failure_never_swaps_routing(backend_cls):
    """Bad callee output in the merged unit -> abort; originals keep serving
    correct results."""
    p = backend_cls(FusionPolicy(min_observations=1, merge_cost_s=0.0, enabled=False))
    try:
        w = jnp.eye(8) * 0.5
        deploy_pair(p, w)
        x = jnp.ones((2, 8))
        ref = np.asarray(p.invoke("A", x))  # records canaries for A and B
        before = {n: id(p.registry.resolve(n)) for n in ("A", "B")}

        # Corrupt the callee's SPEC: the merged unit is built from specs, so
        # its inlined B produces garbage while the live instances are intact.
        good = p._specs["B"]
        p._specs["B"] = FunctionSpec("B", lambda ctx, params, xx: jnp.tanh(xx @ params) + 100.0, good.params)
        p.policy.enabled = True
        p.handler.edges[("A", "B")] = EdgeStats(sync_count=5, total_wait_s=1.0)
        p.merger.submit("A", "B")

        events = p.merger.merge_log
        assert events and not events[-1].healthy
        assert events[-1].reason == "health check failed"
        assert events[-1].checked_members  # it DID replay canaries before aborting
        assert {n: id(p.registry.resolve(n)) for n in ("A", "B")} == before
        n_events = len(events)
        np.testing.assert_allclose(np.asarray(p.invoke("A", x)), ref, rtol=1e-6)
        # the failed edge is quarantined: fresh traffic re-observing the hot
        # edge must NOT re-trigger the doomed merge (control-plane spin)
        assert len(p.merger.merge_log) == n_events
        if backend_cls is OrchestratedBackend:
            live_members = {tuple(sorted(w.instance.members)) for w in p._workers.values()}
            assert ("A", "B") not in live_members
    finally:
        p.shutdown()


def test_failed_group_not_rebuilt_via_other_edges():
    """After a group fails its health check, OTHER edges resolving to the
    same member set must not pay the doomed build again."""
    p = TinyJaxBackend(FusionPolicy(min_observations=1, merge_cost_s=0.0, enabled=False))
    try:
        w = jnp.eye(8) * 0.5
        p.deploy(FunctionSpec("A", lambda ctx, params, x: ctx.call("B", x @ params), w))
        p.deploy(FunctionSpec("B", lambda ctx, params, x: ctx.call("C", x @ params), w))
        p.deploy(FunctionSpec("C", lambda ctx, params, x: jnp.tanh(x @ params), w))
        p.invoke("A", jnp.ones((2, 8)))  # canaries for A, B and C

        good = p._specs["C"]
        p._specs["C"] = FunctionSpec("C", lambda ctx, params, x: jnp.tanh(x @ params) + 100.0, good.params)
        p.policy.enabled = True
        p.policy.commit("A", "B")  # as if an earlier A+B merge was healthy
        p.handler.edges[("B", "C")] = EdgeStats(sync_count=5, total_wait_s=1.0)
        p.merger.submit("B", "C")  # builds {A,B,C}, health check fails
        assert len(p.merger.merge_log) == 1 and not p.merger.merge_log[0].healthy
        assert set(p.merger.merge_log[0].members) == {"A", "B", "C"}

        p.handler.edges[("A", "C")] = EdgeStats(sync_count=5, total_wait_s=1.0)
        p.merger.submit("A", "C")  # same doomed group via a different edge
        assert len(p.merger.merge_log) == 1, "doomed group must not be rebuilt"
    finally:
        p.shutdown()


def test_detach_instance_stops_never_promoted_worker():
    p = OrchestratedBackend(FusionPolicy(enabled=False))
    try:
        p.deploy(FunctionSpec("B", lambda ctx, params, x: x + 1, None))
        spec = p.spec_of("B")
        candidate = FunctionInstance({"B": spec}, p)
        p.attach_instance(candidate)
        worker = p._workers[candidate.instance_id]
        assert worker.thread.is_alive()

        p.detach_instance(candidate)
        worker.thread.join(timeout=10)
        assert not worker.thread.is_alive(), "detached pod's request loop must exit"
        assert candidate.instance_id not in p._workers
        # routing never pointed at the candidate; B still serves
        assert int(p.invoke("B", jnp.int32(1))) == 2
    finally:
        p.shutdown()


def test_detach_is_noop_for_unknown_instance():
    p = OrchestratedBackend(FusionPolicy(enabled=False))
    try:
        p.deploy(FunctionSpec("B", lambda ctx, params, x: x, None))
        ghost = FunctionInstance({"B": p.spec_of("B")}, p)  # never attached
        p.detach_instance(ghost)  # must not raise or disturb live workers
        assert int(p.invoke("B", jnp.int32(7))) == 7
    finally:
        p.shutdown()
