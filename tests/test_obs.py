"""Observability layer: flight recorder, span trees, critical-path
attribution, deterministic exporters, and the coherent-stats regression.

The conservation tests are the core contract: every finished request trace's
leaf phases (plus parent self-time) sum EXACTLY to its end-to-end latency —
`attribute` computes the residual and these tests assert it is zero, on the
serial invoke path, the coalesced async path, and under fault injection.
"""
import json
import random
import threading
from concurrent.futures import wait

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FunctionSpec, FusionPolicy, TinyJaxBackend
from repro.obs import (
    CONTROL_TRACE_ID,
    FlightRecorder,
    SpanRecord,
    Tracer,
    attribute,
    attribute_trace,
    chrome_trace,
    dumps_chrome,
    prometheus_text,
)
from repro.scheduler import RequestScheduler, VirtualClock

REAL_BUDGET_S = 10.0


def settle(clock, n=1):
    clock.wait_for_waiters(n, timeout=5.0)


# ------------------------------------------------------- flight recorder


def _rec(trace_id, span_id, t0=0.0, t1=1.0, parent=1, name="s", cat="execute",
         ph="X"):
    return SpanRecord(trace_id, span_id, parent, name, cat, t0, t1, ph)


def test_flight_recorder_drop_oldest_and_counter():
    rec = FlightRecorder(capacity_per_thread=4)
    for i in range(10):
        rec.append(_rec(1, i + 1, t0=float(i)))
    records = rec.snapshot()
    assert len(records) == 4
    assert [r.span_id for r in records] == [7, 8, 9, 10], "oldest must drop"
    assert rec.dropped() == 6
    rec.clear()
    assert rec.snapshot() == [] and rec.dropped() == 0


def test_flight_recorder_never_mixes_threads_buffers():
    rec = FlightRecorder(capacity_per_thread=8)

    def writer(tid):
        for i in range(8):
            rec.append(_rec(tid, i + 1))

    threads = [threading.Thread(target=writer, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(rec.snapshot()) == 32 and rec.dropped() == 0


# ----------------------------------------------------------- attribution


def test_attribution_exact_conservation_unit():
    # root [0, 10]; queue-wait [0, 3]; compute [3, 10] with a nested child
    records = [
        _rec(7, 1, 0.0, 10.0, parent=0, name="req", cat="invoke"),
        _rec(7, 2, 0.0, 3.0, parent=1, cat="queue-wait"),
        _rec(7, 3, 3.0, 10.0, parent=1, cat="batch-compute"),
        _rec(7, 4, 4.0, 6.0, parent=3, cat="cross-function-sync"),
    ]
    out = attribute_trace(records)
    assert out["conserved"] and out["residual_s"] == pytest.approx(0.0, abs=1e-12)
    assert out["wall_s"] == 10.0
    assert out["phases"]["queue-wait"] == pytest.approx(3.0)
    # compute self-time excludes the nested sync wait
    assert out["phases"]["batch-compute"] == pytest.approx(5.0)
    assert out["phases"]["cross-function-sync"] == pytest.approx(2.0)
    assert sum(out["phases"].values()) == pytest.approx(out["wall_s"])


def test_attribution_flags_orphans_and_negative_self_time():
    orphan = [
        _rec(1, 1, 0.0, 4.0, parent=0, cat="invoke"),
        _rec(1, 5, 1.0, 2.0, parent=99, cat="execute"),  # parent never emitted
    ]
    assert not attribute_trace(orphan)["conserved"]
    overlap = [
        _rec(2, 1, 0.0, 4.0, parent=0, cat="invoke"),
        _rec(2, 2, 0.0, 3.0, parent=1, cat="execute"),
        _rec(2, 3, 0.0, 3.0, parent=1, cat="execute"),  # siblings overlap: 6 > 4
    ]
    assert not attribute_trace(overlap)["conserved"]
    # unfinished root: trace not attributable at all
    assert attribute_trace([_rec(3, 4, 0.0, 1.0, parent=1)]) is None


# ----------------------------------------- serial invoke path (platform)


def test_serial_invoke_trace_conserves_latency():
    p = TinyJaxBackend(FusionPolicy(enabled=False))
    try:
        w = jnp.eye(8)

        def fn_b(ctx, params, x):
            return jnp.tanh(x @ params)

        def fn_a(ctx, params, x):
            return ctx.call("B", x @ params)

        p.deploy(FunctionSpec("A", fn_a, w))
        p.deploy(FunctionSpec("B", fn_b, w))
        for _ in range(3):
            p.invoke("A", jnp.ones((2, 8)))
        results = attribute(p.tracer.recorder.snapshot())
        invokes = [r for r in results if r["kind"] == "invoke"]
        assert len(invokes) == 3
        for r in invokes:
            assert r["conserved"], r
            assert r["residual_s"] == pytest.approx(0.0, abs=1e-9)
            assert sum(r["phases"].values()) == pytest.approx(r["wall_s"])
            assert "execute" in r["phases"]
            # unfused chain: the A->B boundary hop must appear as sync wait
            assert "cross-function-sync" in r["phases"]
    finally:
        p.shutdown()


def test_fused_chain_records_inline_not_boundary_edges():
    p = TinyJaxBackend(FusionPolicy(min_observations=2, merge_cost_s=0.0))
    try:
        w = jnp.eye(8)

        def fn_b(ctx, params, x):
            return jnp.tanh(x @ params)

        def fn_a(ctx, params, x):
            return ctx.call("B", x @ params)

        p.deploy(FunctionSpec("A", fn_a, w))
        p.deploy(FunctionSpec("B", fn_b, w))
        for _ in range(8):
            p.invoke("A", jnp.ones((2, 8)))
        p.merger.wait_idle()
        assert [m for m in p.merger.merge_log if m.healthy]
        records = p.tracer.recorder.snapshot()
        # post-merge the edge is compiled away: a fused-inline control event
        # exists, and the LAST invoke's trace has no boundary hop
        control = [r for r in records if r.trace_id == CONTROL_TRACE_ID]
        assert any(r.name.startswith("fused-inline:A->B") for r in control)
        assert any(r.name.startswith("merge:") for r in control)
        results = attribute(records)
        last = [r for r in results if r["kind"] == "invoke"][-1]
        assert last["conserved"]
        assert "cross-function-sync" not in last["phases"]
    finally:
        p.shutdown()


# ------------------------------------- coalesced async path (sim, exact)


def _sim_once(fail_batches=(), n=6):
    """Scripted virtual-time sim: n arrivals 4ms apart into a 16ms window,
    dispatch optionally failing for chosen batch ordinals. Returns the
    tracer's records."""
    clock = VirtualClock()
    tracer = Tracer(clock=clock)
    seen = {"batches": 0}

    def dispatch(name, argss):
        seen["batches"] += 1
        if seen["batches"] in fail_batches:
            raise RuntimeError("injected dispatch failure")
        return [a[0] for a in argss]

    sched = RequestScheduler(dispatch, clock=clock, max_batch=4,
                             max_delay_ms=16.0, tracer=tracer)
    try:
        futs = []
        for i in range(n):
            futs.append(sched.submit("f", (i,)))
            settle(clock)
            clock.advance(0.004)
        settle(clock)
        clock.advance(0.1)  # drain every window
        done, not_done = wait(futs, timeout=5)
        assert not not_done
        clock.assert_elapsed_real_below(REAL_BUDGET_S)
        return tracer.recorder.snapshot()
    finally:
        sched.shutdown()


def test_batched_trace_phases_tile_wall_exactly():
    records = _sim_once()
    results = attribute(records)
    reqs = [r for r in results if r["kind"] == "invoke_async"]
    assert len(reqs) == 6
    for r in reqs:
        assert r["conserved"], r
        assert r["residual_s"] == 0.0, "phases must tile the wall EXACTLY"
        assert {"queue-wait", "window-wait", "batch-compute"} <= set(r["phases"])
        assert sum(r["phases"].values()) == pytest.approx(r["wall_s"], abs=1e-12)
    # the shared execution is its own trace, referenced by the members
    batches = [r for r in results if r["kind"] == "batch"]
    assert batches, "batched dispatch must mint a batch trace"
    member_refs = {
        r.args["batch_trace"]
        for r in records
        if r.cat == "batch-compute" and r.args and "batch_trace" in r.args
    }
    assert member_refs == {b["trace_id"] for b in batches}


def test_conservation_holds_under_fault_injection():
    rng = random.Random(0xBAD5EED)
    for trial in range(4):
        fail = {rng.randint(1, 2)}  # 8 arrivals / max_batch 4 -> 2 batches
        records = _sim_once(fail_batches=fail, n=8)
        results = attribute(records)
        reqs = [r for r in results if r["kind"] == "invoke_async"]
        assert len(reqs) == 8, "failed requests must still close their traces"
        assert any(r["attrs"] and r["attrs"].get("error") for r in reqs)
        for r in reqs:
            assert r["conserved"], (trial, r)
            assert r["residual_s"] == 0.0


def test_same_seed_sim_exports_byte_identical_traces():
    a = dumps_chrome(chrome_trace(_sim_once()))
    b = dumps_chrome(chrome_trace(_sim_once()))
    assert a == b, "same-seed virtual-clock runs must export identical bytes"
    doc = json.loads(a)
    events = doc["traceEvents"]
    assert events and doc["displayTimeUnit"] == "ms"
    for ev in events:
        # perfetto-loadable trace_event schema: complete spans carry dur,
        # instants a scope, metadata only names
        assert ev["ph"] in ("X", "i", "M")
        if ev["ph"] == "X":
            assert ev["dur"] >= 0 and isinstance(ev["ts"], float | int)


# ------------------------------------------- satellite 1: coherent stats


def test_stats_snapshot_totals_conserved_under_concurrent_invokes():
    """Regression: stats() used to assemble billing/latency/per-instance
    views under SEPARATE meter lock acquisitions, so a concurrent sampler
    could read a per-function total that disagreed with the per-instance
    split. One coherent snapshot must make them equal in every sample."""
    p = TinyJaxBackend(FusionPolicy(enabled=False))
    try:
        w = jnp.eye(4)
        p.deploy(FunctionSpec("F", lambda ctx, params, x: x @ params, w))
        stop = threading.Event()
        mismatches = []

        def sampler():
            while not stop.is_set():
                s = p.stats()
                by_fn = s["billing"]["by_function"]
                fn_calls = sum(d["calls"] for d in by_fn.values())
                inst_calls = sum(
                    d["calls"]
                    for f in s["replicas"]["functions"].values()
                    for d in f["billing"].values()
                )
                if fn_calls != inst_calls:
                    mismatches.append((fn_calls, inst_calls))
                gb_fn = sum(d["gb_s"] for d in by_fn.values())
                if abs(gb_fn - s["billing"]["total_gb_s"]) > 1e-12:
                    mismatches.append(("gb", gb_fn, s["billing"]["total_gb_s"]))

        def invoker():
            x = jnp.ones((1, 4))
            for _ in range(40):
                p.invoke("F", x)

        sam = threading.Thread(target=sampler)
        sam.start()
        workers = [threading.Thread(target=invoker) for _ in range(4)]
        for t in workers:
            t.start()
        for t in workers:
            t.join()
        stop.set()
        sam.join(timeout=5)
        assert not mismatches, mismatches[:5]
        assert sum(
            d["calls"] for d in p.stats()["billing"]["by_function"].values()
        ) == 160
    finally:
        p.shutdown()


# ------------------------------------------------- exporters / prometheus


def test_prometheus_dump_flattens_stats_and_trace_aggregates():
    p = TinyJaxBackend(FusionPolicy(enabled=False))
    try:
        w = jnp.eye(4)
        p.deploy(FunctionSpec("F", lambda ctx, params, x: x @ params, w))
        for _ in range(3):
            p.invoke("F", jnp.ones((1, 4)))
        text = prometheus_text(p)
        names = {line.split("{")[0].split(" ")[0] for line in text.splitlines()}
        assert "repro_trace_spans_total" in names
        assert "repro_trace_dropped_total" in names
        assert "repro_trace_phase_seconds" in names
        assert "repro_dispatch_compiles_total" in names
        assert "repro_dispatch_host_syncs_total" in names
        assert any(n.startswith("repro_stats_billing") for n in names)
        # every line is valid exposition: metric[{labels}] value
        for line in text.splitlines():
            head, _, value = line.rpartition(" ")
            assert head and float(value) is not None
    finally:
        p.shutdown()


def test_prometheus_endpoint_serves_metrics():
    import urllib.request

    p = TinyJaxBackend(FusionPolicy(enabled=False))
    server = None
    try:
        from repro.obs import serve_prometheus

        w = jnp.eye(4)
        p.deploy(FunctionSpec("F", lambda ctx, params, x: x @ params, w))
        p.invoke("F", jnp.ones((1, 4)))
        server = serve_prometheus(p, port=0)
        port = server.server_address[1]
        body = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=10).read().decode()
        assert "repro_trace_spans_total" in body
    finally:
        if server is not None:
            server.shutdown()
        p.shutdown()


# ------------------------------------ satellite 2: dispatch tracer re-arm


def test_dispatch_tracer_rearm_is_refcounted_and_restores_patches():
    import numpy
    import jax

    from repro.analysis.dispatch import TRACER

    orig_asarray = numpy.asarray
    orig_device_get = jax.device_get
    base = TRACER.snapshot()
    x = jnp.ones((2, 2))
    TRACER.arm()
    TRACER.arm()  # nested window (overhead gate inside smoke gate)
    np.asarray(x)
    TRACER.disarm()
    assert TRACER.armed, "inner disarm must not tear down the outer window"
    np.asarray(x)
    TRACER.disarm()
    np.asarray(x)  # fully disarmed: not counted
    TRACER.disarm()  # stray disarm: no underflow, no double-unpatch
    d = TRACER.delta(base)
    assert d.host_syncs == 2
    assert numpy.asarray is orig_asarray, "patches must restore the ORIGINAL"
    assert jax.device_get is orig_device_get
    assert not TRACER.armed


def test_dispatch_tracer_concurrent_arm_disarm_never_leaks_patch():
    import numpy

    from repro.analysis.dispatch import TRACER

    orig_asarray = numpy.asarray
    x = jnp.ones((2, 2))
    errors = []

    def churn():
        try:
            for _ in range(50):
                TRACER.arm()
                np.asarray(x)
                TRACER.disarm()
        except Exception as exc:  # pragma: no cover - the assert is the test
            errors.append(exc)

    threads = [threading.Thread(target=churn) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert not TRACER.armed
    assert numpy.asarray is orig_asarray, "unbalanced unpatch leaked a wrapper"


# --------------------------------------------------------- registry pins


def test_retain_tracers_survives_platform_drop():
    import gc

    from repro.obs import export_all_chrome, live_tracers, retain_tracers

    retain_tracers(True)
    try:
        p = TinyJaxBackend(FusionPolicy(enabled=False))
        w = jnp.eye(4)
        p.deploy(FunctionSpec("F", lambda ctx, params, x: x @ params, w))
        p.invoke("F", jnp.ones((1, 4)))
        tracer = p.tracer
        p.shutdown()
        del p
        gc.collect()
        assert tracer in live_tracers(), "retention must pin dropped platforms"
    finally:
        retain_tracers(False)
    gc.collect()
