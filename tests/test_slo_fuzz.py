"""Property/fuzz tests for scheduler conservation under multi-class traffic.

Hand-rolled seeded fuzzing (no hypothesis dependency): random arrival
bursts, classes, priorities, and shapes through a real RequestScheduler,
with faults injected at every observability seam. The conservation
properties that must hold on EVERY trace:

* every submitted future resolves exactly once (result or exception) —
  no hangs, no double resolution, no drops;
* echoed results match their request payloads (no cross-request mixups);
* no batch ever mixes SLO classes or shapes;
* raising metrics sinks (request-level and batch-level) and raising
  dispatches never strand a client or kill a dispatcher;
* shutdown drains everything already admitted.
"""
import random
import threading
import time
from concurrent.futures import Future, wait

import pytest

from repro.analysis import LockGraph, patched_locks
from repro.scheduler import (
    BEST_EFFORT,
    IMMEDIATE,
    PRIORITY_HIGH,
    AdmissionQueue,
    OverloadShedError,
    PendingRequest,
    RequestScheduler,
    SLOClass,
)

CLASSES = [
    BEST_EFFORT,
    SLOClass("gold", 10.0),
    SLOClass("silver", 80.0),
    IMMEDIATE,
]
#: class identity is encoded into the request payload (an int tag) so the
#: dispatch callable itself can verify single-class batches without any
#: scheduler-internal access
CLASS_TAG = {s.name: i for i, s in enumerate(CLASSES)}


@pytest.mark.parametrize("seed", [0xC0FFEE, 7, 20260727])
def test_conservation_random_traces(seed):
    rng = random.Random(seed)
    n_requests = 250
    violations: list[str] = []
    fail_every = rng.randrange(7, 15)  # some batches raise from dispatch
    dispatched = {"batches": 0}

    def dispatch(name, args_list):
        dispatched["batches"] += 1
        tags = {a[1] for a in args_list}
        if len(tags) != 1:
            violations.append(f"mixed-class batch: {args_list}")
        shapes = {len(a[2]) for a in args_list}
        if len(shapes) != 1:
            violations.append(f"mixed-shape batch: {args_list}")
        if dispatched["batches"] % fail_every == 0:
            raise RuntimeError("injected dispatch fault")
        return [a[0] * 3 for a in args_list]

    calls = {"n": 0}

    def flaky_request_sink(name, lat_s, k):
        calls["n"] += 1
        if calls["n"] % 5 == 0:
            raise RuntimeError("injected metrics fault")

    # provlint runtime net: every lock the scheduler stack creates during
    # this trace (scheduler lock, lane cvs, future conditions) records its
    # acquisition order; the trace fails if the observed graph has a cycle.
    # The patch must stay active through the submit loop because lane cvs
    # are created lazily on first submit per (class, shape) key.
    lock_graph = LockGraph()
    lock_patch = patched_locks(lock_graph)
    lock_patch.__enter__()
    sched = RequestScheduler(
        dispatch,
        max_batch=rng.choice([2, 4, 8]),
        max_delay_ms=rng.choice([0.0, 1.0, 3.0]),
        adaptive=rng.random() < 0.5,
        on_request_done=flaky_request_sink,
    )
    futs: list[tuple[int, Future]] = []
    resolution_counts: dict[int, int] = {}
    counts_lock = threading.Lock()

    def stamp(idx):
        def cb(_fut):
            with counts_lock:
                resolution_counts[idx] = resolution_counts.get(idx, 0) + 1
        return cb

    try:
        i = 0
        while i < n_requests:
            # a burst of 1..12 concurrent submits, then (maybe) a tiny pause
            # so windows sometimes expire and sometimes coalesce
            for _ in range(rng.randrange(1, 13)):
                if i >= n_requests:
                    break
                slo = rng.choice(CLASSES)
                shape = (0,) * rng.randrange(1, 4)  # 1..3-tuple: distinct treedefs
                pri = PRIORITY_HIGH if (slo is IMMEDIATE and rng.random() < 0.5) else 0
                fut = sched.submit(
                    "f", (i, CLASS_TAG[slo.name], shape),
                    slo=None if pri else slo, priority=pri,
                )
                fut.add_done_callback(stamp(i))
                futs.append((i, fut))
                i += 1
            if rng.random() < 0.3:
                time.sleep(rng.choice([0.0005, 0.002]))

        done, not_done = wait([f for _, f in futs], timeout=30)
        lock_patch.__exit__(None, None, None)
        lock_patch = None
        assert not not_done, f"{len(not_done)} futures hung (conservation violated)"
        lock_graph.assert_acyclic()
        assert lock_graph.edges(), "lock instrumentation never fired"
        assert not violations, violations[:3]
        ok = failed = shed = 0
        for idx, fut in futs:
            exc = fut.exception()
            if exc is None:
                assert fut.result() == idx * 3, f"request {idx} got another's result"
                ok += 1
            elif isinstance(exc, OverloadShedError):
                # real overload shedding (PR 5): a trace mixing strict
                # classes with best-effort backlog past the bound may shed —
                # a legitimate exactly-once resolution, never a hang
                shed += 1
            else:
                assert "injected dispatch fault" in str(exc)
                failed += 1
        assert ok + failed + shed == n_requests
        assert failed > 0, "the fault schedule must actually have fired"
        # give done-callbacks a moment, then check exactly-once resolution
        deadline = time.perf_counter() + 5.0
        while time.perf_counter() < deadline:
            with counts_lock:
                if len(resolution_counts) >= n_requests:
                    break
            time.sleep(0.001)
        with counts_lock:
            assert len(resolution_counts) == n_requests
            assert all(c == 1 for c in resolution_counts.values()), (
                "a future resolved more than once"
            )
    finally:
        if lock_patch is not None:
            lock_patch.__exit__(None, None, None)
        sched.shutdown()
        lock_graph.assert_acyclic()  # shutdown's drain is part of the trace
    # post-shutdown: nothing accepted, nothing hung
    with pytest.raises(RuntimeError):
        sched.submit("f", (0, 0, (0,)))


@pytest.mark.parametrize("seed", [3, 99])
def test_queue_level_on_batch_done_faults_never_strand_futures(seed):
    """The same conservation property one layer down: a randomly raising
    batch-level observability callback (the scheduler's _record_batch is
    only one possible sink) must never leave a future unresolved or kill
    the dispatcher."""
    rng = random.Random(seed)

    def boom(name, batch, t_done):
        if rng.random() < 0.5:
            raise ValueError("injected on_batch_done fault")

    q = AdmissionQueue(
        "f", lambda name, args_list: [a[0] for a in args_list],
        max_batch=4, max_delay_s=0.001, on_batch_done=boom,
    )
    try:
        reqs = []
        for i in range(60):
            r = PendingRequest((i,), Future(), time.perf_counter())
            q.put(r)
            reqs.append(r)
            if rng.random() < 0.2:
                time.sleep(0.0005)
        done, not_done = wait([r.future for r in reqs], timeout=10)
        assert not not_done
        assert [r.future.result() for r in reqs] == list(range(60))
        assert q.thread.is_alive()
    finally:
        q.stop()
        q.thread.join(timeout=5)


def test_cancelled_future_cannot_kill_the_dispatcher():
    """A client cancelling its future mid-flight must not orphan the rest
    of the batch (the InvalidStateError path in _resolve)."""
    gate = threading.Event()

    def dispatch(name, args_list):
        gate.wait(5.0)
        return [a[0] for a in args_list]

    sched = RequestScheduler(dispatch, max_batch=4, max_delay_ms=0.0)
    try:
        first = sched.submit("f", (0,))  # occupies the dispatcher
        time.sleep(0.02)
        rest = [sched.submit("f", (i,)) for i in range(1, 4)]
        rest[0].cancel()  # queued, not yet running: cancellable
        gate.set()
        done, not_done = wait([first] + rest[1:], timeout=10)
        assert not not_done, "a cancelled co-batched future stranded the others"
        assert [f.result() for f in [first] + rest[1:]] == [0, 2, 3]
        assert sched.submit("f", (9,)).result(timeout=5) == 9  # dispatcher alive
    finally:
        sched.shutdown()
