"""Deterministic SLO-scheduler simulations on the virtual clock.

Every test here drives real scheduler machinery — window controllers,
dispatcher threads, admission lanes — through *scripted virtual time*:
arrivals land at exact simulated instants, windows expire because the test
advances the clock, and nothing ever sleeps on the wall clock. Each
simulation closes with the virtual clock's elapsed-real-time guard, which
fails the test if the simulated seconds were in fact waited out for real.

Covers (ISSUE 4 satellite 1 + the early-close regression):
* the queueing-model window controller under scripted bursty / trickle /
  overload / mixed-class traces (pure, single-threaded, exact);
* full-scheduler sims asserting window decisions and per-class deadline
  hits (strict classes meet target, best-effort still batches);
* the PRIORITY_HIGH/strict-class early-close preempting an in-flight
  coalesce timer instead of waiting out its residual delay.
"""
import math
import threading
from concurrent.futures import wait

import numpy as np
import pytest

from repro.core.billing import BillingMeter
from repro.scheduler import (
    BEST_EFFORT,
    IMMEDIATE,
    PRIORITY_HIGH,
    AdaptiveConfig,
    QueueingWindow,
    RequestScheduler,
    SLOClass,
    VirtualClock,
)
from repro.serving.continuous import ContinuousBatcher
from repro.serving.engine import PagedPrefillJob
from repro.serving.kvpool import KVArena

#: Real-time budget for one whole simulation (CI boxes are slow; the point
#: is that simulated time is orders of magnitude larger than real time).
REAL_BUDGET_S = 10.0


def settle(clock, n=1):
    """Wait (real, bounded, event-driven) until the dispatcher threads are
    parked on the virtual clock, so the next advance is observed."""
    clock.wait_for_waiters(n, timeout=5.0)


# ----------------------------------------------------------- virtual clock


def test_virtual_clock_advance_and_sleep():
    clock = VirtualClock()
    assert clock.now() == 0.0
    clock.advance(1.5)
    assert clock.now() == pytest.approx(1.5)
    woke = []

    def sleeper():
        clock.sleep(2.0)
        woke.append(clock.now())

    th = threading.Thread(target=sleeper, daemon=True)
    th.start()
    settle(clock)
    clock.advance(1.0)
    assert not woke, "sleep must not return before its virtual deadline"
    settle(clock)
    clock.advance(1.0)
    th.join(timeout=5)
    assert woke and woke[0] == pytest.approx(3.5)
    with pytest.raises(ValueError):
        clock.advance(-1)
    clock.assert_elapsed_real_below(REAL_BUDGET_S)


def test_virtual_clock_real_time_guard_fires():
    clock = VirtualClock()
    with pytest.raises(AssertionError, match="real time"):
        clock.assert_elapsed_real_below(0.0)


def test_virtual_clock_wait_on_wakes_on_notify_and_advance():
    clock = VirtualClock()
    cv = threading.Condition()
    state = {"returns": 0}

    def waiter():
        with cv:
            clock.wait_on(cv, 10.0)
            state["returns"] += 1

    th = threading.Thread(target=waiter, daemon=True)
    th.start()
    settle(clock)
    with cv:
        cv.notify_all()  # a real notify wakes it without any time passing
    th.join(timeout=5)
    assert state["returns"] == 1
    clock.assert_elapsed_real_below(REAL_BUDGET_S)


# ------------------------------------------- controller: scripted traces


def cfg(**kw):
    kw.setdefault("max_delay_s", 0.020)
    return AdaptiveConfig(**kw)


def test_controller_bursty_trace_grows_best_effort_window():
    """Dense arrivals the seed window misses: the model's fill-time window
    (time for target_occupancy*max_batch arrivals at the EWMA rate) grows
    the lane toward packing whole bursts."""
    win = QueueingWindow(8, 0.001, cfg())
    t = 0.0
    for _ in range(30):  # singletons 2ms apart: rate 500/s
        win.observe_batch([t], closed_full=False, service_s=0.0005)
        t += 0.002
    # steady state: fill time = (0.75*8 - 1) * 2ms = 10ms
    assert 0.004 < win.delay_s <= 0.020
    assert win.arrival_rate_rps == pytest.approx(500.0, rel=0.05)


def test_controller_trickle_trace_decays_to_zero_for_any_class():
    """A gap beyond the window cap means no co-rider can be caught: the
    window must go to the minimum for best-effort AND strict classes."""
    for slo in (BEST_EFFORT, SLOClass("gold", 200.0)):
        win = QueueingWindow(8, 0.020, cfg(), slo=slo)
        t = 0.0
        for _ in range(30):
            win.observe_batch([t], closed_full=False, service_s=0.001)
            t += 0.100
        assert win.delay_s == 0.0, f"trickle must zero the window for {slo.name}"


def test_controller_strict_window_spends_only_target_slack():
    """A strict lane's window is bounded by slack_fraction * (target -
    predicted_wait - service): the target can never be violated by the
    batching delay the controller itself added."""
    slo = SLOClass("gold", 10.0)
    c = cfg(slack_fraction=0.5)
    win = QueueingWindow(8, 0.020, c, slo=slo)
    t = 0.0
    for _ in range(40):  # arrivals 1ms apart, service 2ms per batch
        win.observe_batch([t, t + 0.001], closed_full=False, service_s=0.002)
        t += 0.002
    slack = slo.target_s - win.predicted_wait_s() - 0.002
    assert win.delay_s <= 0.5 * slack + 1e-9
    assert win.delay_s < 0.020, "the throughput cap must not govern a strict lane"
    # the same trace with a loose target is fill-time-bound instead
    loose = QueueingWindow(8, 0.020, c, slo=SLOClass("silver", 500.0))
    t = 0.0
    for _ in range(40):
        loose.observe_batch([t, t + 0.001], closed_full=False, service_s=0.002)
        t += 0.002
    assert loose.delay_s > win.delay_s, "looser targets buy bigger windows"


def test_controller_overload_collapses_strict_window_to_greedy():
    """Offered load above the lane's batched capacity drives the predicted
    M/G/1 wait to infinity — the slack is gone, and the strict lane must
    degrade to greedy FIFO (zero window), the pre-SLO behavior."""
    slo = SLOClass("gold", 20.0)
    win = QueueingWindow(4, 0.010, cfg(), slo=slo)
    t = 0.0
    for _ in range(40):  # 4-wide batches every 2ms = 2000 rps offered...
        win.observe_batch([t, t + 5e-4, t + 1e-3, t + 1.5e-3], closed_full=True,
                          service_s=0.008)  # ...against 4/8ms = 500 rps capacity
        t += 0.002
    assert win.predicted_wait_s() == math.inf
    assert win.delay_s == 0.0, "no slack left: strict lane must stop adding delay"


def test_controller_zero_target_class_never_opens_a_window():
    # regression: an operator min_delay_s floor (a best-effort timer-churn
    # knob shared by every lane's config) must not re-open a window on a
    # zero-target lane after the first retune, nor hold a slack-starved
    # strict lane above zero
    for c in (cfg(), cfg(min_delay_s=0.001)):
        win = QueueingWindow(8, 0.020, c, slo=IMMEDIATE)
        assert win.delay_s == 0.0  # seed is clamped by the structural bound
        t = 0.0
        for _ in range(20):
            win.observe_batch([t, t + 0.001], closed_full=False, service_s=0.001)
            t += 0.002
        assert win.delay_s == 0.0, f"min_delay_s leaked into a zero-target lane: {win.delay_s}"
    # a strict lane with NO slack degrades to exactly greedy, floor or not
    starved = QueueingWindow(4, 0.010, cfg(min_delay_s=0.001), slo=SLOClass("g", 20.0))
    t = 0.0
    for _ in range(40):  # offered 2000 rps vs 500 rps capacity: rho >= 1
        starved.observe_batch([t, t + 5e-4, t + 1e-3, t + 1.5e-3], closed_full=True,
                              service_s=0.008)
        t += 0.002
    assert starved.delay_s == 0.0


def test_controller_mixed_class_trace_orders_windows_by_target():
    """One shared arrival trace, three targets: the steady-state windows
    must order inversely to strictness, and every strict window must fit
    inside its own slack."""
    classes = [SLOClass("gold", 8.0), SLOClass("silver", 60.0), BEST_EFFORT]
    wins = {s.name: QueueingWindow(8, 0.004, cfg(), slo=s) for s in classes}
    t = 0.0
    for _ in range(50):  # pairs 1.5ms apart, 3ms service
        for w in wins.values():
            w.observe_batch([t, t + 0.0015], closed_full=False, service_s=0.003)
        t += 0.003
    gold, silver, be = (wins[s.name].delay_s for s in classes)
    assert gold <= silver <= be, (gold, silver, be)
    assert gold < 0.004, "an 8ms target with 3ms service leaves little slack"


# ---------------------------------------------- scheduler: virtual traces


def make_sim(dispatch=None, **kw):
    clock = VirtualClock()
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_delay_ms", 16.0)
    sched = RequestScheduler(
        dispatch or (lambda name, a: [x[0] for x in a]), clock=clock, **kw
    )
    return clock, sched


def test_sim_window_expiry_dispatches_batch_with_zero_real_sleeps():
    """Two arrivals inside one window dispatch as one batch exactly when
    the virtual window expires — 16ms of simulated waiting, ~0 real."""
    batches = []
    clock, sched = make_sim(lambda n, a: (batches.append(len(a)), [x[0] for x in a])[1])
    try:
        f1 = sched.submit("f", (1,))
        settle(clock)
        clock.advance(0.004)
        f2 = sched.submit("f", (2,))
        settle(clock)
        clock.advance(0.012)  # window (16ms) expires exactly now
        done, not_done = wait([f1, f2], timeout=5)
        assert not not_done
        assert batches == [2], "both arrivals must ride one batch"
        st = sched.stats()
        # virtual latencies: first waited the whole window, second 12ms
        assert st["p95_ms"] == pytest.approx(16.0, abs=0.5)
        clock.assert_elapsed_real_below(REAL_BUDGET_S)
    finally:
        sched.shutdown()


def test_sim_trickle_decays_window_then_lone_requests_stop_waiting():
    """Adaptive lane under a scripted 100ms trickle: the controller zeroes
    the window, after which lone requests resolve with no virtual delay at
    all (the old static-window tax is gone) — and no real time passed."""
    clock, sched = make_sim(adaptive=True, max_delay_ms=16.0,
                            adaptive_config=AdaptiveConfig(max_delay_s=0.016))
    try:
        lats = []
        for i in range(14):  # multiplicative decay: ~10 batches to zero
            t0 = clock.now()
            fut = sched.submit("f", (i,))
            settle(clock)  # dispatcher parks: on the window, or idle if done
            if not fut.done():
                # advance exactly the lane's current window — the precise
                # virtual instant the batch must dispatch
                w = max(q.max_delay_s for q in sched._queues.values())
                clock.advance(w + 1e-4)
            assert fut.result(timeout=5) == i
            lats.append(clock.now() - t0)
            clock.advance(0.100 - (clock.now() - t0))  # trickle spacing
        assert lats[0] > 0.010, "the seed window makes the first lone request wait"
        assert lats[-1] == pytest.approx(0.0, abs=1e-6), (
            f"decayed window must stop taxing lone requests: {lats}"
        )
        rows = sched.window_snapshot()
        assert rows and rows[0]["max_delay_ms"] == 0.0
        clock.assert_elapsed_real_below(REAL_BUDGET_S)
    finally:
        sched.shutdown()


def test_sim_mixed_classes_hit_deadlines_and_never_share_batches():
    """Three classes on one (function, shape) under a scripted mixed trace:
    every batch is single-class, the strict class's worst-case virtual
    latency stays under its target, and class_stats reports conformance."""
    gold = SLOClass("gold", 40.0)      # static window = 10ms
    silver = SLOClass("silver", 160.0)  # static window = 16ms (cap)
    batch_classes = []
    BE_TAG, SILVER_TAG, GOLD_TAG = 0, 1, 2

    def dispatch(name, args_list):
        batch_classes.append({a[1] for a in args_list})
        return [a[0] for a in args_list]

    clock, sched = make_sim(dispatch, max_batch=4, max_delay_ms=16.0)
    try:
        futs = []
        for round_ in range(12):
            t0 = clock.now()
            futs.append(sched.submit("f", (round_, BE_TAG), slo=BEST_EFFORT))
            futs.append(sched.submit("f", (round_, SILVER_TAG), slo=silver))
            settle(clock)
            clock.advance(0.002)
            futs.append(sched.submit("f", (round_, GOLD_TAG), slo=gold))
            futs.append(sched.submit("f", (round_, GOLD_TAG), slo=gold))
            # drive this round to completion: every window <= 16ms
            for _ in range(20):
                if all(f.done() for f in futs):
                    break
                settle(clock)
                clock.advance(0.002)
            clock.advance(0.050 - (clock.now() - t0))  # next round
        done, not_done = wait(futs, timeout=5)
        assert not not_done
        for mix in batch_classes:
            assert len(mix) == 1, f"cross-class batch observed: {batch_classes}"
        classes = sched.class_stats()
        assert set(classes) == {"best-effort", "gold", "silver"}
        assert classes["gold"]["p95_ms"] <= gold.target_p95_ms
        assert classes["gold"]["met"] is True
        assert classes["silver"]["met"] is True
        assert classes["best-effort"]["met"] is None  # no target to meet
        # strict arrivals preempted the open best-effort/silver windows, so
        # nothing best-effort waited past the strict arrival offset + window
        assert classes["best-effort"]["p95_ms"] <= 16.0 + 0.5
        clock.assert_elapsed_real_below(REAL_BUDGET_S)
    finally:
        sched.shutdown()


def test_sim_strict_burst_batches_within_slack():
    """Strict traffic still batches when the target leaves room: four gold
    arrivals inside the 10ms static window ride one batch, with the worst
    virtual latency well under target."""
    gold = SLOClass("gold", 40.0)
    batches = []
    clock, sched = make_sim(lambda n, a: (batches.append(len(a)), [x[0] for x in a])[1],
                            max_batch=4, max_delay_ms=16.0)
    try:
        futs = [sched.submit("f", (i,), slo=gold) for i in range(4)]
        done, not_done = wait(futs, timeout=5)  # full batch: no advance needed
        assert not not_done
        assert batches == [4]
        assert sched.class_stats()["gold"]["p95_ms"] <= gold.target_p95_ms
        clock.assert_elapsed_real_below(REAL_BUDGET_S)
    finally:
        sched.shutdown()


# ------------------------------------------------- early-close regression


def test_sim_strict_arrival_preempts_in_flight_window_timer():
    """Regression (ISSUE 4): a PRIORITY_HIGH / strict-class request arriving
    while a looser lane's window timer is mid-flight must preempt that
    timer. Before the fix, per-class lanes left the best-effort window
    running its full residual delay — here 2 simulated seconds — so the
    collected batch (and, with one dispatcher per key, the urgent request
    behind it) waited it out. Now: everything resolves with NO additional
    virtual time."""
    clock, sched = make_sim(max_batch=8, max_delay_ms=2000.0)
    try:
        normal = [sched.submit("f", (i,)) for i in range(3)]
        settle(clock)
        clock.advance(0.020)  # the window is now in flight, 1.98s residual
        settle(clock)
        urgent = sched.submit("f", (99,), priority=PRIORITY_HIGH)
        done, not_done = wait(normal + [urgent], timeout=5)
        assert not not_done, "strict arrival failed to preempt the window timer"
        assert urgent.result() == 99
        st = sched.stats()
        # no virtual time passed after the preempt: every latency is bounded
        # by the 20ms that elapsed before the urgent arrival
        assert st["p95_ms"] <= 20.0 + 0.5, st
        clock.assert_elapsed_real_below(REAL_BUDGET_S)
    finally:
        sched.shutdown()


def test_sim_preempt_is_edge_triggered_not_latched():
    """A preempt with no window open must NOT shorten the next window: the
    lane would otherwise degrade to greedy dispatch forever after the first
    strict arrival."""
    batches = []
    clock, sched = make_sim(lambda n, a: (batches.append(len(a)), [x[0] for x in a])[1],
                            max_batch=4, max_delay_ms=16.0)
    try:
        # strict arrival with NO best-effort window open anywhere
        assert sched.submit("f", (0,), priority=PRIORITY_HIGH).result(timeout=5) == 0
        # now a best-effort window must still run its full 16ms
        f1 = sched.submit("f", (1,))
        settle(clock)
        clock.advance(0.008)
        f2 = sched.submit("f", (2,))
        settle(clock)
        assert not f1.done(), "window closed early: preempt latched across batches"
        clock.advance(0.008)
        done, not_done = wait([f1, f2], timeout=5)
        assert not not_done
        assert batches[-1] == 2, "the full window must still coalesce the pair"
        clock.assert_elapsed_real_below(REAL_BUDGET_S)
    finally:
        sched.shutdown()


# ------------------------------------------------------ trough + quiesce


def test_sim_trough_ignores_best_effort_trickle_but_not_strict():
    """The reconciler's trough detector considers deadline-bearing traffic
    only: a best-effort trickle must not block deferred control-plane work
    (the PR 3 failure mode), while recent strict arrivals must."""
    clock, sched = make_sim(max_delay_ms=0.0)
    try:
        for i in range(5):
            assert sched.submit("f", (i,)).result(timeout=5) == i
            assert sched.is_trough(min_quiet_s=0.01), (
                "best-effort trickle must not defeat the trough detector"
            )
            clock.advance(0.005)
        sched.submit("f", (9,), slo=SLOClass("gold", 40.0)).result(timeout=5)
        assert not sched.is_trough(min_quiet_s=0.01), (
            "a fresh strict arrival means a stall would land on deadline traffic"
        )
        clock.advance(0.02)
        assert sched.is_trough(min_quiet_s=0.01)
        clock.assert_elapsed_real_below(REAL_BUDGET_S)
    finally:
        sched.shutdown()


def test_sim_quiesce_times_out_virtually_while_busy():
    """The drain barrier's timeout is virtual too: a blocked dispatch holds
    the barrier until the test advances past the deadline — no real wait."""
    release = threading.Event()

    def dispatch(name, args_list):
        release.wait(5.0)
        return [a[0] for a in args_list]

    clock, sched = make_sim(dispatch, max_delay_ms=0.0)
    try:
        fut = sched.submit("f", (1,))
        # the dispatcher is stuck inside dispatch (not parked on the clock):
        # quiesce from a side thread must observe busy until we advance
        out = {}

        def barrier():
            out["ok"] = sched.quiesce(timeout=0.05)

        th = threading.Thread(target=barrier, daemon=True)
        th.start()
        settle(clock)  # the quiescer parks on the virtual clock
        clock.advance(0.06)  # past the barrier deadline
        th.join(timeout=5)
        assert out["ok"] is False, "quiesce must time out (virtually) while busy"
        release.set()
        assert fut.result(timeout=5) == 1
        assert sched.quiesce(timeout=1.0), "drained pipe must pass the barrier"
        clock.assert_elapsed_real_below(REAL_BUDGET_S)
    finally:
        release.set()
        sched.shutdown()


def test_sim_idle_dispatcher_retires_on_virtual_timeout():
    """Queue retirement rides the virtual clock: 60 simulated idle seconds
    retire the dispatcher instantly in real time."""
    clock, sched = make_sim(idle_timeout_s=60.0, max_delay_ms=0.0)
    try:
        assert sched.submit("f", (1,)).result(timeout=5) == 1
        q = next(iter(sched._queues.values()))
        settle(clock)
        clock.advance(61.0)
        q.thread.join(timeout=5)
        assert not q.thread.is_alive()
        assert sched.stats()["queues"] == 0
        # the key still serves: a fresh queue spins up transparently
        assert sched.submit("f", (2,)).result(timeout=5) == 2
        clock.assert_elapsed_real_below(REAL_BUDGET_S)
    finally:
        sched.shutdown()


def test_sim_immediate_traffic_emits_no_violation_signal():
    """Regression: PRIORITY_HIGH traffic (zero-target class) must not feed
    a 'violated class' signal to the policy — its end-to-end latency always
    includes service time, and before the fix one high-priority request was
    enough to flap fission on every group touching the function."""
    clock, sched = make_sim(max_delay_ms=0.0)
    try:
        for i in range(4):
            assert sched.submit("f", (i,), priority=PRIORITY_HIGH).result(timeout=5) == i
        sig = sched.signals_for("f")
        assert sig.class_p95_ms == (), sig
        assert sig.worst_violation() is None
        # the conformance report still shows the class, with no actionable target
        assert sched.class_stats()["immediate"]["met"] is None
        clock.assert_elapsed_real_below(REAL_BUDGET_S)
    finally:
        sched.shutdown()


def test_sim_violation_signal_ages_out_of_the_recent_window():
    """Regression: the policy's per-class tails are computed over a trailing
    time window. A burst that violated a strict target must stop reporting
    as violated once it is older than the window — an all-time p95 kept a
    recovered class 'violated' for thousands of samples and split currently
    healthy groups."""
    gold = SLOClass("gold", 10.0)
    release = threading.Event()
    release.set()
    clock, sched = make_sim(max_delay_ms=0.0)
    try:
        # a violating burst: hold requests past the target in virtual time
        fut = sched.submit("f", (0,), slo=gold)
        fut.result(timeout=5)
        # fabricate the violation by submitting, advancing past target while
        # the dispatcher is held, then releasing
        gate = threading.Event()

        def slow_dispatch(name, args_list):
            gate.wait(5.0)
            return [a[0] for a in args_list]

        sched._dispatch = slow_dispatch
        f2 = sched.submit("f", (1,), slo=gold)
        for _ in range(50):
            if sched._inflight:
                break
            threading.Event().wait(0.002)  # dispatcher entering dispatch
        clock.advance(0.050)  # 50ms > the 10ms target while in flight
        gate.set()
        assert f2.result(timeout=5) == 1
        sig = sched.signals_for("f")
        assert sig.worst_violation() is not None, "the burst must read as violated"
        clock.advance(6.0)  # past the 5s signal window: violation has aged out
        sig = sched.signals_for("f")
        assert sig.worst_violation() is None, sig
        clock.assert_elapsed_real_below(REAL_BUDGET_S)
    finally:
        release.set()
        sched.shutdown()


# ------------------------------- continuous batcher: chunked prefill sim


class _SimPlatform:
    def __init__(self, clock):
        self.clock = clock
        self.meter = BillingMeter(clock=clock)


class _SimEngine:
    """Timing model of the paged ServingEngine for virtual-clock sims.

    Page bookkeeping is the REAL :class:`KVArena`; the XLA compute is
    replaced by virtual sleeps — ``per_token_s`` per prompt token of
    prefill, ``step_s`` per whole-batch decode step. Those two constants
    are exactly the ratio that makes a long joiner prompt dangerous: an
    80-token prompt costs 40 decode steps' worth of accelerator time, so
    serializing it in front of the batch stalls every resident stream by
    400 simulated ms."""

    def __init__(self, clock, *, per_token_s=0.005, step_s=0.010,
                 num_pages=64, page_size=8, block_width=16):
        self.platform = _SimPlatform(clock)
        self.clock = clock
        self.entry = "sim/embed"
        self.block_width = block_width
        self.per_token_s = per_token_s
        self.step_s = step_s
        import jax.numpy as jnp

        self.arena = KVArena({"sim": 1}, num_pages=num_pages,
                             page_size=page_size, kv_heads=1, head_dim=2,
                             dtype=jnp.float32)

    def _logits(self, batch):
        out = np.zeros((batch, 16), np.float32)
        out[:, 7] = 1.0  # deterministic greedy token, never EOS
        return out

    # ------------- the engine surface the continuous batcher drives

    def begin_prefill_paged(self, seq_id, inputs):
        tokens = np.asarray(inputs["tokens"], np.int32)[0]
        self.arena.alloc(seq_id, len(tokens))
        return PagedPrefillJob(seq_id, tokens, 0)

    def prefill_chunk_paged(self, job, max_tokens):
        c = max(1, min(int(max_tokens), job.remaining))
        self.clock.sleep(c * self.per_token_s)
        job.pos += c
        return self._logits(1) if job.pos >= job.t_in else None

    def prefill_paged(self, seq_id, inputs):
        tokens = np.asarray(inputs["tokens"], np.int32)[0]
        self.arena.alloc(seq_id, len(tokens))
        self.clock.sleep(len(tokens) * self.per_token_s)
        return self._logits(1), len(tokens)

    def paged_decode_step(self, tok, cur, bt, *, write_kv=True):
        self.clock.sleep(self.step_s)
        return self._logits(int(tok.shape[0]))


def _advance_until(clock, dt, pred, max_iters=2000):
    """Drive simulated time on a fixed grid until ``pred()`` holds: settle
    (so the loop thread is parked on the clock), then advance one grid
    step. Every sleep in the sim lands on the 10ms grid, so dt=0.01 hits
    each deadline exactly."""
    for _ in range(max_iters):
        if pred():
            return
        settle(clock)
        clock.advance(dt)
    raise AssertionError("simulation did not converge")


def _run_batcher_sim(serialize_prefill):
    """One strict resident stream + three long-prompt best-effort joiners
    admitted mid-stream, under chunked (default) or serialized prefill.
    Returns (strict result, joiner results, stats)."""
    clock = VirtualClock()
    eng = _SimEngine(clock)
    gold = SLOClass("gold", 100.0)  # 100ms inter-token target
    b = ContinuousBatcher(eng, capacity=4, serialize_prefill=serialize_prefill,
                          min_chunk=2, slack_fraction=0.5)
    try:
        strict_fut = b.submit({"tokens": np.arange(1, 9, dtype=np.int32)[None, :]},
                              60, slo=gold)
        # phase 1: the strict stream reaches steady state (~20 emissions)
        t_joiners = 0.2
        _advance_until(clock, 0.01, lambda: clock.now() >= t_joiners - 1e-9)
        prompt = (np.arange(2, 82, dtype=np.int32) % 13)[None, :]  # 80 tokens
        joiner_futs = [b.submit({"tokens": prompt}, 8) for _ in range(3)]
        if not serialize_prefill:
            # mid-stream co-residency: drive until the first joiner's
            # chunked prefill finishes and seats it — the strict stream
            # must still be emitting at that moment
            _advance_until(clock, 0.01, lambda: b.stats()["active"] >= 2)
            st = b.stats()
            assert not strict_fut.done(), "strict stream must still be mid-flight"
            assert st["prefill_chunks"] > 3, st
        futs = [strict_fut] + joiner_futs
        _advance_until(clock, 0.01, lambda: all(f.done() for f in futs))
        strict = strict_fut.result(timeout=5)
        joiners = [f.result(timeout=5) for f in joiner_futs]
        stats = b.stats()
    finally:
        b.shutdown()
    clock.assert_elapsed_real_below(REAL_BUDGET_S)
    return strict, joiners, stats


def test_sim_chunked_prefill_protects_strict_stream_and_joiners():
    """The tentpole's latency story, end to end on the virtual clock.

    Serialized prefill (the old admit-time path): three 400ms joiner
    prompts run back-to-back in front of the batch, so the strict
    resident's worst inter-token gap blows through its 100ms target and
    already-seated joiners stall behind later arrivals' prompts.

    Chunked prefill: the same trace holds the strict stream's inter-token
    p95 (and max) under target — each chunk is budgeted from the strict
    lane's slack — while joiners still seat mid-stream, and the joiners'
    own emission-to-emission p95 strictly improves."""
    strict_c, joiners_c, stats_c = _run_batcher_sim(serialize_prefill=False)
    strict_s, joiners_s, stats_s = _run_batcher_sim(serialize_prefill=True)
    target_s = 0.100

    # every stream ran to completion in both modes
    assert strict_c["tokens"].shape == strict_s["tokens"].shape == (1, 60)
    for j in joiners_c + joiners_s:
        assert j["tokens"].shape == (1, 8)

    # the serialized baseline really does violate the strict target
    gaps_strict_s = np.asarray(strict_s["step_s"])
    assert gaps_strict_s.max() > target_s, (
        f"baseline not stressful: max strict gap {gaps_strict_s.max():.3f}s"
    )
    assert stats_s["prefill_chunks"] == 0

    # chunked: strict inter-token p95 AND worst case inside the target,
    # with the prompts streamed in as budgeted chunks
    gaps_strict_c = np.asarray(strict_c["step_s"])
    assert np.percentile(gaps_strict_c, 95) <= target_s + 1e-6, gaps_strict_c
    assert gaps_strict_c.max() <= target_s + 1e-6, (
        f"strict stream stalled {gaps_strict_c.max():.3f}s under chunked prefill"
    )
    assert stats_c["prefill_chunks"] >= 30  # 3 x 80-token prompts, <= 8/chunk

    # joiners: emission-to-emission p95 strictly improves — seated joiners
    # no longer absorb later arrivals' whole prompts as one stall
    j_gaps_c = np.concatenate([np.asarray(j["step_s"]) for j in joiners_c])
    j_gaps_s = np.concatenate([np.asarray(j["step_s"]) for j in joiners_s])
    p95_c = float(np.percentile(j_gaps_c, 95))
    p95_s = float(np.percentile(j_gaps_s, 95))
    assert p95_c < p95_s, f"chunked {p95_c:.3f}s !< serialized {p95_s:.3f}s"
    # and not marginally: the serialized tail contains whole-prompt stalls
    assert p95_s > 2 * p95_c, (p95_c, p95_s)
