"""Concurrency stress: client threads hammer `invoke`/`invoke_async` WHILE
the Merger builds, health-checks, and swaps the routing table underneath
them. No response may be lost, billing must stay exact (one record per
request, control-plane canary replays accounted), and every result must
match the serial reference."""
import threading
from concurrent.futures import wait

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FunctionSpec, FusionPolicy, OrchestratedBackend, TinyJaxBackend

BACKENDS = [TinyJaxBackend, OrchestratedBackend]

N_THREADS = 6
REQS_PER_THREAD = 10


def deploy_chain(platform):
    """A -> B -> C, weights chosen so results are deterministic per input."""
    wa = jnp.asarray(np.random.RandomState(0).randn(24, 24).astype(np.float32) * 0.2)
    wb = jnp.asarray(np.random.RandomState(1).randn(24, 24).astype(np.float32) * 0.2)
    wc = jnp.asarray(np.random.RandomState(2).randn(24, 24).astype(np.float32) * 0.2)
    platform.deploy(FunctionSpec("A", lambda ctx, p, x: ctx.call("B", jnp.tanh(x @ p)), wa))
    platform.deploy(FunctionSpec("B", lambda ctx, p, x: ctx.call("C", jnp.tanh(x @ p)), wb))
    platform.deploy(FunctionSpec("C", lambda ctx, p, x: jnp.tanh(x @ p), wc))

    def reference(x):
        return jnp.tanh(jnp.tanh(jnp.tanh(x @ wa) @ wb) @ wc)

    return reference


@pytest.mark.parametrize("backend_cls", BACKENDS)
def test_stress_invocations_race_merge_swap(backend_cls):
    # min_observations is tuned so the first merges trigger MID-traffic:
    # early requests observe the edges, later ones race the swaps.
    p = backend_cls(
        FusionPolicy(min_observations=8, merge_cost_s=0.0),
        max_batch=4, max_delay_ms=2.0,
    )
    try:
        reference = deploy_chain(p)
        inputs = [
            jnp.full((2, 24), 0.1 + 0.05 * (t * REQS_PER_THREAD + i))
            for t in range(N_THREADS)
            for i in range(REQS_PER_THREAD)
        ]
        results: dict[int, np.ndarray] = {}
        errors: list[Exception] = []
        lock = threading.Lock()

        def client(tid: int):
            try:
                futs = []
                for i in range(REQS_PER_THREAD):
                    idx = tid * REQS_PER_THREAD + i
                    if i % 2 == 0:  # alternate serial and scheduled dispatch
                        out = p.invoke("A", inputs[idx])
                        with lock:
                            results[idx] = np.asarray(out)
                    else:
                        futs.append((idx, p.invoke_async("A", inputs[idx])))
                done, not_done = wait([f for _, f in futs], timeout=120)
                assert not not_done, "scheduled requests must all complete"
                for idx, f in futs:
                    with lock:
                        results[idx] = np.asarray(f.result())
            except Exception as exc:  # noqa: BLE001
                with lock:
                    errors.append(exc)

        threads = [threading.Thread(target=client, args=(t,)) for t in range(N_THREADS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
        assert not errors, errors
        p.merger.wait_idle()

        # --- no lost responses, each correct vs serial reference ---
        total = N_THREADS * REQS_PER_THREAD
        assert len(results) == total, "every request must produce a response"
        for idx in range(total):
            np.testing.assert_allclose(
                results[idx], np.asarray(reference(inputs[idx])), rtol=1e-4, atol=1e-5,
                err_msg=f"request {idx} diverged from serial semantics",
            )

        # --- the swap really happened mid-traffic ---
        healthy = [m for m in p.merger.merge_log if m.healthy]
        assert healthy, "fusion must have occurred during the stress run"
        assert {"A", "B", "C"} <= set(healthy[-1].members)

        # --- billing: exactly one record per client request on the entry,
        # plus one per control-plane canary replay of A (no dupes, no losses)
        a_records = [r for r in p.meter.records if r.function == "A"]
        canary_replays = sum("A" in m.checked_members for m in p.merger.merge_log)
        assert len(a_records) == total + canary_replays
    finally:
        p.shutdown()
