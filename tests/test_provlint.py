"""provlint's own tests: fixture snippets pinned to exact diagnostics, the
revert-a-real-fix acceptance demonstrations, the runtime lock recorder, the
dispatch tracer, and the exit-0-at-HEAD CLI gate."""
import subprocess
import sys
import threading
from pathlib import Path

import pytest

from repro.analysis import InstrumentedLock, LockGraph, patched_locks
from repro.analysis import clocklint, lockcheck, lockorder
from repro.analysis.dispatch import DispatchTracer

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "provlint"


def _findings(pass_mod, name, checker="check_source"):
    src = (FIXTURES / name).read_text(encoding="utf-8")
    return getattr(pass_mod, checker)(src, name)


# --------------------------------------------------------------- fixtures


def test_bad_guarded_rmw_pins_both_sites():
    got = {(f.pass_name, f.line) for f in _findings(lockcheck, "bad_guarded_rmw.py")}
    assert ("lock-discipline", 20) in got  # aliased RMW outside _data_lock
    assert ("lock-discipline", 24) in got  # read outside _lock
    assert len(got) == 2


def test_bad_unlocked_policy_pins_the_rmw():
    got = _findings(lockcheck, "bad_unlocked_policy.py")
    assert {(f.pass_name, f.line) for f in got} == {("lock-discipline", 14)}
    assert any("merge_cost_s" in f.message for f in got)


def test_bad_replica_cursor_pins_the_unlocked_rmw():
    """The ISSUE 9 shape: a spread policy's round-robin cursor RMW'd outside
    its lock — both halves of the RMW pin to their exact lines."""
    got = _findings(lockcheck, "bad_replica_cursor.py")
    assert {(f.pass_name, f.line) for f in got} == {
        ("lock-discipline", 23),  # unlocked cursor read
        ("lock-discipline", 24),  # unlocked cursor write-back
    }
    assert all("_cursor" in f.message for f in got)


def test_bad_lock_order_reports_the_cycle():
    got = _findings(lockorder, "bad_lock_order.py")
    assert len(got) == 1
    f = got[0]
    assert f.pass_name == "lock-order"
    assert f.line in (14, 19)  # anchored at one participating nesting
    assert "_a" in f.message and "_b" in f.message


def test_bad_sleep_src_pins_every_raw_time_call():
    got = {(f.pass_name, f.line) for f in _findings(clocklint, "bad_sleep_src.py")}
    assert got == {("clock-hygiene", 7), ("clock-hygiene", 8), ("clock-hygiene", 11)}


def test_bad_sleeping_test_pins_the_sleep():
    got = _findings(clocklint, "bad_sleeping_test.py", "check_test_source")
    assert {(f.pass_name, f.line) for f in got} == {("test-sleep", 6)}


def test_good_fixtures_are_clean():
    assert _findings(lockcheck, "good_guarded.py") == []
    assert _findings(lockorder, "good_guarded.py") == []
    assert _findings(clocklint, "good_test.py", "check_test_source") == []


# ------------------------------------------- revert-a-real-fix acceptance


def test_reverting_pr6_write_prefill_fix_is_caught():
    """Strip ``with self._data_lock:`` from the real ``write_prefill`` and
    the lock-discipline pass must flag the RMW at its exact site."""
    path = "src/repro/serving/kvpool.py"
    src = (REPO / path).read_text(encoding="utf-8")
    assert not lockcheck.check_source(src, path)  # clean at HEAD
    import re
    bad, n = re.subn(
        r"( +)with self\._data_lock:\n((?:\1    .*\n|\n)+?)(?=\1\S|\Z)",
        lambda m: "".join(
            line[4:] if line.strip() else line
            for line in m.group(2).splitlines(keepends=True)
        ),
        src, count=1)
    assert n == 1 and bad != src
    findings = lockcheck.check_source(bad, path)
    assert findings, "de-locking write_prefill must produce findings"
    assert all(f.pass_name == "lock-discipline" for f in findings)
    assert any("data" in f.message and "_data_lock" in f.message for f in findings)


def test_reverting_pr2_merge_cost_fix_is_caught():
    """Move the ``merge_cost_s`` EWMA out from under ``_lock`` in the real
    policy module and the pass reports exactly that line."""
    path = "src/repro/core/policy.py"
    src = (REPO / path).read_text(encoding="utf-8")
    assert not lockcheck.check_source(src, path)  # clean at HEAD
    locked = ("        with self._lock:\n"
              "            self.merge_cost_s = 0.5 * self.merge_cost_s + 0.5 * seconds")
    unlocked = "        self.merge_cost_s = 0.5 * self.merge_cost_s + 0.5 * seconds"
    assert locked in src
    bad = src.replace(locked, unlocked)
    findings = lockcheck.check_source(bad, path)
    assert findings and all("merge_cost_s" in f.message for f in findings)
    # both the read and the write of the RMW land on the de-indented line
    assert {f.line for f in findings} == {bad[: bad.index(unlocked)].count("\n") + 1}


def test_delocking_the_spread_cursor_is_caught():
    """Strip the lock from the real least-outstanding tie rotor and the
    lock-discipline pass flags the cursor RMW at its site — the exact race
    the bad_replica_cursor fixture distills."""
    path = "src/repro/core/registry.py"
    src = (REPO / path).read_text(encoding="utf-8")
    assert not lockcheck.check_source(src, path)  # clean at HEAD
    locked = ("        with self._lock:\n"
              "            i = self._cursor.get(name, 0) % len(tied)\n"
              "            self._cursor[name] = i + 1\n"
              "        return tied[i]")
    unlocked = ("        i = self._cursor.get(name, 0) % len(tied)\n"
                "        self._cursor[name] = i + 1\n"
                "        return tied[i]")
    assert locked in src
    bad = src.replace(locked, unlocked)
    findings = lockcheck.check_source(bad, path)
    assert findings, "de-locking the spread cursor must produce findings"
    assert all(f.pass_name == "lock-discipline" for f in findings)
    assert all("_cursor" in f.message for f in findings)


def test_reverting_pr6_gather_snapshot_fix_is_caught():
    """Move ``gather``'s held/lens snapshot out of the lock (the non-atomic
    snapshot race PR 6 fixed) and the pass flags the unlocked reads."""
    path = "src/repro/serving/kvpool.py"
    src = (REPO / path).read_text(encoding="utf-8")
    marker = ("        with self._lock:\n"
              "            pages = self._held.get(seq_id, [])")
    assert marker in src
    bad = src.replace(
        marker, "        if True:\n            pages = self._held.get(seq_id, [])")
    findings = lockcheck.check_source(bad, path)
    assert any("_held" in f.message for f in findings), findings
    assert any("_block_row_locked" in f.message for f in findings), findings


# ----------------------------------------------------- runtime lock graph


def test_instrumented_lock_records_and_detects_cycles():
    g = LockGraph()
    a = InstrumentedLock(g, name="A")
    b = InstrumentedLock(g, name="B")
    with a:
        with b:
            pass
    g.assert_acyclic()
    assert g.edges()["A"] == {"B"}

    done = threading.Event()

    def inverted():
        with b:
            with a:
                pass
        done.set()

    t = threading.Thread(target=inverted)
    t.start()
    t.join(5)
    assert done.is_set()
    with pytest.raises(AssertionError, match="cycle"):
        g.assert_acyclic()
    assert g.find_cycle() is not None


def test_instrumented_rlock_reentry_is_not_a_self_edge():
    g = LockGraph()
    r = InstrumentedLock(g, name="R", reentrant=True)
    with r:
        with r:
            pass
    g.assert_acyclic()
    assert g.edges().get("R", set()) == set()


def test_patched_locks_instruments_condition_over_lock():
    g = LockGraph()
    with patched_locks(g):
        lk = threading.Lock()
        cv = threading.Condition(lk)
        other = threading.Lock()
    assert isinstance(lk, InstrumentedLock)
    with cv:
        with other:
            cv.notify_all()  # exercises _is_owned on the duck-typed lock
    g.assert_acyclic()
    assert any(g.edges().values()), "no edges recorded through the condition"
    # patch is scoped: new locks outside are the real thing again
    assert not isinstance(threading.Lock(), InstrumentedLock)


# ------------------------------------------------------- dispatch tracer


def test_dispatch_tracer_counts_compiles_and_host_syncs():
    jax = pytest.importorskip("jax")
    import numpy as np

    import jax.numpy as jnp

    tracer = DispatchTracer()
    tracer.arm()
    try:
        base = tracer.snapshot()

        @jax.jit
        def f(x):
            return x * 2 + 1

        x = jnp.arange(8.0)
        x2 = x + 1  # compiled here, not inside the steady-state window
        y = f(x)  # first call: one backend compile
        d1 = tracer.delta(base)
        assert d1.compiles >= 1
        mid = tracer.snapshot()
        y = f(x2)  # cache hit: zero new compiles
        np.asarray(y)  # one counted device->host sync
        np.asarray(np.arange(4))  # numpy->numpy: NOT counted
        d2 = tracer.delta(mid)
        assert d2.compiles == 0
        assert d2.host_syncs == 1
        tracer.note_decode_step()
        tracer.note_kernel_call("attention", y)
        tracer.note_kernel_call("attention", np.arange(3))  # not a jax.Array
        d3 = tracer.delta(mid)
        assert d3.decode_steps == 1
        assert tracer.kernel_calls == {"attention": 1}
    finally:
        tracer.disarm()
    # disarmed: nothing counts
    after = tracer.snapshot()
    np.asarray(jnp.arange(3.0))
    assert tracer.delta(after).host_syncs == 0


# ------------------------------------------------------------- CLI gate


def test_lint_cli_exits_zero_at_head(tmp_path):
    report = tmp_path / "report.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "--root", str(REPO),
         "--json", str(report)],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    import json
    data = json.loads(report.read_text())
    assert data["ok"] is True and data["findings"] == []
