"""Per-arch smoke tests (reduced configs, one fwd/train step on CPU) +
model-level correctness properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch, reduced_config
from repro.configs.base import ShapeConfig
from repro.models.model import build_model
from repro.models.params import init_params

TRAIN = ShapeConfig("smoke_train", 32, 2, "train")
PREFILL = ShapeConfig("smoke_prefill", 32, 2, "prefill")
DECODE = ShapeConfig("smoke_decode", 32, 2, "decode")

# Archs whose reduced config still takes minutes of XLA compile on CPU; their
# smoke cells run via `-m slow` (the hybrid family keeps tier-1 coverage
# through tests/test_serving.py::test_hybrid_monolithic_chain).
SLOW_COMPILE_ARCHS = {"zamba2-7b"}


def arch_params(archs):
    return [
        pytest.param(a, marks=pytest.mark.slow) if a in SLOW_COMPILE_ARCHS else a
        for a in archs
    ]


@pytest.mark.parametrize("arch", arch_params(sorted(ARCHS)))
def test_arch_smoke_train_step(arch):
    """REDUCED config of the same family: one loss+grad step, shapes + no NaNs."""
    cfg = reduced_config(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = model.make_inputs(TRAIN, jax.random.PRNGKey(1))
    (loss, metrics), grads = jax.jit(jax.value_and_grad(model.loss_fn, has_aux=True))(params, batch)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    assert all(jnp.isfinite(g).all() for g in jax.tree.leaves(grads)), f"{arch}: NaN grads"
    assert float(metrics["loss"]) == pytest.approx(float(loss))


@pytest.mark.parametrize("arch", arch_params(sorted(ARCHS)))
def test_arch_smoke_serve_paths(arch):
    cfg = reduced_config(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    logits, cache = jax.jit(model.prefill_fn)(params, model.make_inputs(PREFILL, jax.random.PRNGKey(1)))
    assert logits.shape == (2, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), f"{arch}: prefill NaNs"
    dec_in = model.make_inputs(DECODE, jax.random.PRNGKey(2))
    dec_cache = init_params(model.cache_defs(DECODE), jax.random.PRNGKey(3))
    logits2, new_cache = jax.jit(model.decode_fn)(params, dec_in, dec_cache)
    assert logits2.shape == (2, cfg.vocab_size)
    assert jnp.isfinite(logits2).all(), f"{arch}: decode NaNs"
    assert jax.tree_util.tree_structure(new_cache) == jax.tree_util.tree_structure(dec_cache)


@pytest.mark.parametrize("arch", arch_params(["llama3.2-1b", "stablelm-1.6b", "mamba2-370m", "zamba2-7b"]))
def test_prefill_then_decode_matches_full_forward(arch):
    """Serving-path correctness: prefill a prompt, decode the next token —
    logits must match a prefill over the extended prompt (same cache math)."""
    cfg = reduced_config(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    t = 16
    tokens = jax.random.randint(jax.random.PRNGKey(5), (2, t + 1), 0, cfg.vocab_size, jnp.int32)

    logits_a, cache = jax.jit(model.prefill_fn)(params, {"tokens": tokens[:, :t]})
    # grow attention caches by one slot so decode can write at position t
    def grow(x):
        if x.ndim >= 3 and x.shape[-3] == t:  # (.., B, S, KV, hd) seq dim
            pad = [(0, 0)] * x.ndim
            pad[-3] = (0, 1)
            return jnp.pad(x, pad)
        return x
    cache = jax.tree.map(grow, cache)
    batch = {"tokens": tokens[:, t:], "cur_len": jnp.full((2,), t, jnp.int32)}
    logits_b, _ = jax.jit(model.decode_fn)(params, batch, cache)

    logits_full, _ = jax.jit(model.prefill_fn)(params, {"tokens": tokens})
    # SSM-family decode uses the recurrent form vs the chunked dual form in
    # prefill: mathematically identical, but bf16 rounding reorders through
    # exp() decay products -> wider tolerance than for attention archs.
    loose = cfg.family in ("ssm", "hybrid")
    np.testing.assert_allclose(
        np.asarray(logits_b, np.float32),
        np.asarray(logits_full, np.float32),
        rtol=0.2 if loose else 3e-2,
        atol=0.5 if loose else 3e-2,
    )


def test_causality_future_tokens_do_not_change_past():
    cfg = reduced_config(get_arch("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    t = 16
    tok1 = jax.random.randint(jax.random.PRNGKey(1), (1, t), 0, cfg.vocab_size, jnp.int32)
    tok2 = tok1.at[:, -1].set((tok1[:, -1] + 1) % cfg.vocab_size)
    # last-token logits after t-1 tokens must be identical
    l1, _ = jax.jit(model.prefill_fn)(params, {"tokens": tok1[:, : t - 1]})
    l2, _ = jax.jit(model.prefill_fn)(params, {"tokens": tok2[:, : t - 1]})
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_moe_is_dropless_with_ample_capacity():
    """With capacity_factor >> 1, MoE output == explicit per-token loop."""
    import dataclasses

    from repro.models import moe as moe_mod

    cfg = dataclasses.replace(
        reduced_config(get_arch("qwen3-moe-30b-a3b")), capacity_factor=8.0
    )
    defs = moe_mod.moe_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32).astype(jnp.bfloat16)
    y, metrics = moe_mod.apply_moe(params, x, cfg)
    assert float(metrics["moe_dropped"]) == 0.0

    # explicit reference: per-token top-k expert mix
    xf = x.reshape(-1, cfg.d_model)
    logits = xf.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    w = w / w.sum(-1, keepdims=True)
    outs = []
    for i in range(xf.shape[0]):
        acc = 0
        for j in range(cfg.num_experts_per_tok):
            eidx = int(idx[i, j])
            gate = jax.nn.silu((xf[i] @ params["wi_gate"][eidx]).astype(jnp.float32))
            up = (xf[i] @ params["wi_up"][eidx]).astype(jnp.float32)
            acc = acc + float(w[i, j]) * ((gate * up).astype(jnp.bfloat16) @ params["wo"][eidx]).astype(jnp.float32)
        outs.append(acc)
    expect = jnp.stack(outs).reshape(2, 8, cfg.d_model)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(expect, np.float32), rtol=5e-2, atol=5e-2
    )


def test_moe_router_weights_normalized():
    cfg = reduced_config(get_arch("phi3.5-moe-42b-a6.6b"))
    from repro.models import moe as moe_mod

    params = init_params(moe_mod.moe_defs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model)).astype(jnp.bfloat16)
    y, metrics = moe_mod.apply_moe(params, x, cfg)
    assert y.shape == x.shape
    assert float(metrics["moe_aux"]) > 0.0  # aux loss is live
