"""ContinuousBatcher: join/leave semantics, SLO slot lanes, shedding."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced_config
from repro.core import FusionPolicy, TinyJaxBackend
from repro.models.model import build_model
from repro.scheduler.slo import BEST_EFFORT, ClassLanes, SLOClass
from repro.serving import ContinuousBatcher, ServingEngine, ShedError


@pytest.fixture(scope="module")
def paged_engine():
    cfg = reduced_config(get_arch("llama3.2-1b"))
    model = build_model(cfg)
    platform = TinyJaxBackend(FusionPolicy(min_observations=2, merge_cost_s=0.0))
    engine = ServingEngine(model, platform, max_len=64, kv_pages=64, kv_page_size=16)
    yield engine
    platform.shutdown()


# ------------------------------------------------------------- ClassLanes


def test_class_lanes_strictest_first_fifo_within():
    lanes = ClassLanes()
    strict = SLOClass("strict", 20.0)
    std = SLOClass("std", 200.0)
    lanes.push("be1")
    lanes.push("std1", std)
    lanes.push("be2")
    lanes.push("s1", strict)
    lanes.push("s2", strict)
    order = [lanes.pop()[0] for _ in range(5)]
    assert order == ["s1", "s2", "std1", "be1", "be2"]
    assert lanes.pop() is None


def test_class_lanes_requeue_front_and_redefinition():
    lanes = ClassLanes()
    std = SLOClass("std", 200.0)
    lanes.push("a", std)
    lanes.push("b", std)
    item, slo = lanes.pop()
    lanes.requeue(item, slo)
    assert lanes.pop()[0] == "a"  # requeued item comes back first
    with pytest.raises(ValueError):
        lanes.push("x", SLOClass("std", 999.0))
    assert lanes.depth("std") == 1 and lanes.depth() == 1


# ------------------------------------------------------- batcher semantics


def test_batcher_matches_per_request_generate(paged_engine):
    """Ragged joins/leaves must not change any request's tokens: the
    continuous batch at capacity 4 (masked slots, mixed lengths) produces
    exactly what solo dense generate produces."""
    engine = paged_engine
    prompts = [jnp.full((1, 4 + 3 * i), 3 + i, jnp.int32) for i in range(3)]
    gens = [6, 9, 5]
    refs = [np.asarray(engine.generate({"tokens": p}, steps=g)[0])
            for p, g in zip(prompts, gens)]
    cb = ContinuousBatcher(engine, capacity=4)
    try:
        futs = [cb.submit({"tokens": p}, g) for p, g in zip(prompts, gens)]
        for f, r in zip(futs, refs):
            res = f.result(timeout=300)
            np.testing.assert_array_equal(res["tokens"], r)
            assert res["pages"] >= 1
        stats = cb.stats()
        assert stats["completed"] == 3 and stats["tokens"] == sum(gens)
    finally:
        cb.shutdown()
    engine.arena.check_consistency()
    assert engine.arena.used_pages() == 0


def test_strict_class_preempts_slot_assignment(paged_engine):
    """With one slot busy, a strict arrival that lands AFTER a best-effort
    one still takes the freed slot first."""
    engine = paged_engine
    cb = ContinuousBatcher(engine, capacity=1)
    try:
        prompt = jnp.full((1, 4), 5, jnp.int32)
        occupant = cb.submit({"tokens": prompt}, 40)
        deadline = time.time() + 60
        while cb.stats()["active"] == 0 and time.time() < deadline:
            time.sleep(0.005)  # occupant admitted
        be = cb.submit({"tokens": prompt}, 25)
        strict = cb.submit({"tokens": prompt}, 6, slo=SLOClass("interactive", 50.0))
        strict.result(timeout=300)
        assert not be.done(), "best-effort must not have been assigned the slot first"
        be.result(timeout=300)
        occupant.result(timeout=300)
    finally:
        cb.shutdown()


def test_batcher_sheds_best_effort_beyond_queue_bound(paged_engine):
    engine = paged_engine
    cb = ContinuousBatcher(engine, capacity=1, max_queue=1)
    try:
        prompt = jnp.full((1, 4), 7, jnp.int32)
        occupant = cb.submit({"tokens": prompt}, 30)
        deadline = time.time() + 60
        while cb.stats()["active"] == 0 and time.time() < deadline:
            time.sleep(0.005)
        queued = cb.submit({"tokens": prompt}, 4)       # depth 1 (bound)
        overflow = cb.submit({"tokens": prompt}, 4)     # best-effort: shed
        with pytest.raises(ShedError):
            overflow.result(timeout=10)
        # strict traffic is never shed by the queue bound
        strict = cb.submit({"tokens": prompt}, 4, slo=SLOClass("interactive", 50.0))
        assert strict.result(timeout=300)["tokens"].shape == (1, 4)
        queued.result(timeout=300)
        occupant.result(timeout=300)
        assert cb.stats()["shed"] == 1
    finally:
        cb.shutdown()


def test_unservable_prompt_fails_fast_not_starves(paged_engine):
    """A prompt that can NEVER fit (more pages than the table holds) must
    fail its own future immediately instead of requeueing forever and
    starving every lane behind it."""
    from repro.serving import ArenaFull

    engine = paged_engine
    cb = ContinuousBatcher(engine, capacity=2)
    try:
        too_long = jnp.full((1, engine.max_len + 16), 3, jnp.int32)
        doomed = cb.submit({"tokens": too_long}, 4)
        with pytest.raises(ArenaFull):
            doomed.result(timeout=30)
        # prompt fits but prompt + generation outgrows the block table: must
        # ALSO fail fast (admitting would blow up mid-flight and take the
        # whole co-resident batch down)
        overgen = cb.submit({"tokens": jnp.full((1, 8), 3, jnp.int32)}, engine.max_len)
        with pytest.raises(ArenaFull):
            overgen.result(timeout=30)
        # admission keeps flowing for servable requests behind it
        ok = cb.submit({"tokens": jnp.full((1, 4), 3, jnp.int32)}, 4)
        assert ok.result(timeout=300)["tokens"].shape == (1, 4)
    finally:
        cb.shutdown()


def test_cancelled_future_does_not_poison_batch(paged_engine):
    """A client cancelling its future must not fail co-resident requests or
    kill the decode loop (regression: InvalidStateError out of _finish)."""
    engine = paged_engine
    cb = ContinuousBatcher(engine, capacity=2)
    try:
        prompt = jnp.full((1, 4), 11, jnp.int32)
        ref = np.asarray(engine.generate({"tokens": prompt}, steps=20)[0])
        f1 = cb.submit({"tokens": prompt}, 20)
        f2 = cb.submit({"tokens": prompt}, 20)
        f1.cancel()  # may or may not win the race with admission; both fine
        res2 = f2.result(timeout=300)
        np.testing.assert_array_equal(res2["tokens"], ref)
        # the loop survived: a fresh request still serves
        f3 = cb.submit({"tokens": prompt}, 5)
        np.testing.assert_array_equal(f3.result(timeout=300)["tokens"], ref[:, :5])
        engine.arena.check_consistency()
    finally:
        cb.shutdown()


def test_chunked_prefill_matches_dense(paged_engine):
    """A long prompt forced through many small chunks (padded buffers,
    per-chunk causal offsets, scatter into pages) must produce the same
    tokens as solo dense generate, bit for bit."""
    engine = paged_engine
    prompt = jnp.asarray((np.arange(1, 41) * 5)[None, :] % 97, jnp.int32)
    ref = np.asarray(engine.generate({"tokens": prompt}, steps=8)[0])
    cb = ContinuousBatcher(engine, capacity=2, prefill_chunk=7)
    try:
        res = cb.submit({"tokens": prompt}, 8).result(timeout=300)
        np.testing.assert_array_equal(res["tokens"], ref)
        assert cb.stats()["prefill_chunks"] >= 6  # 40 tokens / 7 per chunk
    finally:
        cb.shutdown()
    engine.arena.check_consistency()
    assert engine.arena.used_pages() == 0


def test_serialized_prefill_flag_matches_dense(paged_engine):
    """The serialize_prefill=True comparison baseline still serves the old
    admit-time full-prefill path, bit-identical too."""
    engine = paged_engine
    prompt = jnp.asarray((np.arange(1, 23) * 7)[None, :] % 89, jnp.int32)
    ref = np.asarray(engine.generate({"tokens": prompt}, steps=6)[0])
    cb = ContinuousBatcher(engine, capacity=2, serialize_prefill=True)
    try:
        res = cb.submit({"tokens": prompt}, 6).result(timeout=300)
        np.testing.assert_array_equal(res["tokens"], ref)
        assert cb.stats()["prefill_chunks"] == 0
    finally:
        cb.shutdown()
    engine.arena.check_consistency()


def test_shared_prefix_cow_parity(paged_engine):
    """Two requests sharing a whole prompt, resident TOGETHER and then
    diverging through decode: the second is served from the first's pages
    by reference (prefix-cache hit), its first divergent write copy-on-
    writes the shared tail page, and BOTH streams stay bit-identical to
    unshared dense generate."""
    engine = paged_engine
    arena = engine.arena
    # 40-token prompt, page 16: 2 full pages + a partial tail page the two
    # residents share until their decode writes diverge onto it
    prompt = jnp.asarray((np.arange(3, 43) * 11)[None, :] % 101, jnp.int32)
    ref = np.asarray(engine.generate({"tokens": prompt}, steps=12)[0])
    hits0, cow0 = arena.shared_hits, arena.cow_copies
    cb = ContinuousBatcher(engine, capacity=2)
    try:
        f1 = cb.submit({"tokens": prompt}, 12)
        f2 = cb.submit({"tokens": prompt}, 12)
        r1 = f1.result(timeout=300)
        r2 = f2.result(timeout=300)
        np.testing.assert_array_equal(r1["tokens"], ref)
        np.testing.assert_array_equal(r2["tokens"], ref)
        assert arena.shared_hits > hits0, "second request must hit the prefix cache"
        assert arena.cow_copies > cow0, "divergent tail write must copy-on-write"
        # the sharer's amortized bill is strictly below its nominal pages
        assert min(r1["amortized_pages"], r2["amortized_pages"]) < min(r1["pages"], r2["pages"])
    finally:
        cb.shutdown()
    engine.arena.check_consistency()
    assert engine.arena.used_pages() == 0


def test_shared_prefix_then_divergent_prompt_parity(paged_engine):
    """Partial-prefix sharing: request B's prompt shares only the first
    full pages of A's prompt then diverges IN the prompt — B prefills only
    its private suffix yet must match its own dense reference exactly."""
    engine = paged_engine
    base = (np.arange(5, 45) * 13) % 103
    prompt_a = jnp.asarray(base[None, :], jnp.int32)                  # 40 tokens
    prompt_b = jnp.asarray(
        np.concatenate([base[:32], (base[:8] + 1) % 103])[None, :], jnp.int32
    )  # same 2 full pages, different tail
    ref_a = np.asarray(engine.generate({"tokens": prompt_a}, steps=6)[0])
    ref_b = np.asarray(engine.generate({"tokens": prompt_b}, steps=6)[0])
    cb = ContinuousBatcher(engine, capacity=2)
    try:
        fa = cb.submit({"tokens": prompt_a}, 6)
        fb = cb.submit({"tokens": prompt_b}, 6)
        np.testing.assert_array_equal(fa.result(timeout=300)["tokens"], ref_a)
        np.testing.assert_array_equal(fb.result(timeout=300)["tokens"], ref_b)
    finally:
        cb.shutdown()
    engine.arena.check_consistency()
    assert engine.arena.used_pages() == 0


def test_batcher_eos_leaves_early(paged_engine):
    """A request whose greedy token hits eos_id leaves at that step."""
    engine = paged_engine
    prompt = jnp.full((1, 4), 9, jnp.int32)
    ref, _ = engine.generate({"tokens": prompt}, steps=10)
    toks = np.asarray(ref)[0]
    eos = int(toks[4])  # force an early exit at the 5th token
    cb = ContinuousBatcher(engine, capacity=2)
    try:
        res = cb.submit({"tokens": prompt}, 10, eos_id=eos).result(timeout=300)
        got = res["tokens"][0]
        assert got[-1] == eos and len(got) <= 5
        np.testing.assert_array_equal(got, toks[: len(got)])
    finally:
        cb.shutdown()


def test_serve_trace_phases_sum_to_e2e_latency(paged_engine):
    """Every served request's span tree must tile its wall time exactly:
    queue-wait + prefill-stall (+ chunk self-time) + batch-compute account
    for submit-to-done, with no negative or unexplained residue."""
    from repro.obs import attribute, build_trees

    engine = paged_engine
    tracer = engine.platform.tracer
    tracer.recorder.clear()
    cb = ContinuousBatcher(engine, capacity=2)
    try:
        prompts = [jnp.full((1, 4 + 5 * i), 3 + i, jnp.int32) for i in range(3)]
        futs = [cb.submit({"tokens": p}, 5) for p in prompts]
        for f in futs:
            f.result(timeout=300)
    finally:
        cb.shutdown()
    records = tracer.recorder.snapshot()
    serve = [r for r in build_trees(records).values()
             if r[1].cat == "serve"]
    assert len(serve) == 3
    for tree in serve:
        res = attribute(list(tree.values()))[0]
        assert res["conserved"], res
        assert res["residual_s"] == 0.0
        phases = res["phases"]
        assert {"queue-wait", "prefill-stall", "batch-compute"} <= set(phases)
        assert abs(sum(phases.values()) - res["wall_s"]) <= 1e-9
        assert all(v >= -1e-9 for v in phases.values()), phases
