"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.ssd_scan import ssd_scan

RNG = jax.random.PRNGKey(7)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,t,h,kv,hd", [
    (2, 256, 4, 2, 64),
    (1, 128, 8, 8, 32),
    (2, 256, 4, 1, 64),   # MQA
    (1, 512, 2, 2, 128),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(b, t, h, kv, hd, causal, dtype):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, t, h, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, t, kv, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, t, kv, hd), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64, interpret=True)
    expect = ref.mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(expect, np.float32), **tol(dtype))


@pytest.mark.parametrize("b,s,h,kv,hd", [(2, 512, 4, 2, 64), (3, 1024, 8, 1, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_vs_ref(b, s, h, kv, hd, dtype):
    ks = jax.random.split(RNG, 4)
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32).astype(dtype)
    cur = jax.random.randint(ks[3], (b,), 1, s, jnp.int32)
    out = decode_attention(q, k, v, cur, block_k=256, interpret=True)
    expect = ref.decode_attn_ref(q, k, v, cur)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(expect, np.float32), **tol(dtype))


@pytest.mark.parametrize("b,pages,page,h,kv,hd", [
    (3, 9, 128, 4, 2, 64),
    (2, 5, 256, 8, 1, 32),   # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention_vs_contiguous(b, pages, page, h, kv, hd, dtype):
    """Block-table-indirect kernel == gather-to-contiguous + dense oracle."""
    from repro.kernels.paged_attention import gather_pages, paged_decode_attention

    ks = jax.random.split(RNG, 5)
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[1], (pages, page, kv, hd), jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[2], (pages, page, kv, hd), jnp.float32).astype(dtype)
    width = 3
    # ragged sequences through a shuffled table; padded rows hit page 0
    bt = jax.random.randint(ks[3], (b, width), 1, pages, jnp.int32)
    cur = jax.random.randint(ks[4], (b,), 1, width * page, jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, cur, interpret=True)
    expect = ref.decode_attn_ref(q, gather_pages(kp, bt), gather_pages(vp, bt), cur)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(expect, np.float32), **tol(dtype))


def test_paged_decode_attention_scratch_rows_masked():
    """A masked slot (all-scratch row, cur_len 0) must produce EXACT zeros
    — not a mean of scratch-page garbage — and not disturb live rows."""
    from repro.kernels.paged_attention import gather_pages, paged_decode_attention

    ks = jax.random.split(RNG, 3)
    b, pages, page, h, kv, hd = 2, 4, 128, 2, 2, 32
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (pages, page, kv, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (pages, page, kv, hd), jnp.float32)
    bt = jnp.array([[1, 2], [0, 0]], jnp.int32)
    cur = jnp.array([page + 7, 0], jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, cur, interpret=True)
    np.testing.assert_array_equal(np.asarray(out[1]), 0.0)
    expect = ref.decode_attn_ref(
        q[:1], gather_pages(kp, bt[:1]), gather_pages(vp, bt[:1]), cur[:1]
    )
    np.testing.assert_allclose(np.asarray(out[:1]), np.asarray(expect), rtol=2e-5, atol=2e-5)


def test_decode_attention_length_edge_cases():
    b, s, h, kv, hd = 2, 256, 2, 2, 32
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
    for cur in (jnp.array([1, s]), jnp.array([s, 1])):
        out = decode_attention(q, k, v, cur, block_k=128, interpret=True)
        expect = ref.decode_attn_ref(q, k, v, cur)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,t,h,grp,p,n,chunk", [
    (2, 128, 4, 1, 32, 16, 32),
    (1, 256, 2, 2, 64, 32, 64),
    (1, 64, 2, 1, 16, 8, 64),  # single chunk
])
def test_ssd_scan_vs_ref(b, t, h, grp, p, n, chunk):
    ks = jax.random.split(RNG, 5)
    x = jax.random.normal(ks[0], (b, t, h, p), jnp.float32)
    bm = jax.random.normal(ks[1], (b, t, grp, n), jnp.float32) * 0.5
    cm = jax.random.normal(ks[2], (b, t, grp, n), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, t, h), jnp.float32))
    a_log = jax.random.normal(ks[4], (h,), jnp.float32) * 0.3
    d_skip = jnp.ones((h,), jnp.float32)
    out = ssd_scan(x, bm, cm, dt, a_log, d_skip, chunk=chunk, interpret=True)
    expect, _ = ref.ssd_ref(x, bm, cm, dt, a_log, d_skip)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect, np.float32), rtol=5e-4, atol=5e-4)


def test_models_ssd_chunked_matches_oracle():
    """The model's jnp chunked SSD (the lowering path) == naive O(T^2) oracle."""
    from repro.models.ssm import ssd_chunked

    ks = jax.random.split(RNG, 5)
    b, t, h, grp, p, n = 2, 96, 4, 2, 16, 8
    x = jax.random.normal(ks[0], (b, t, h, p), jnp.float32)
    bm = jax.random.normal(ks[1], (b, t, grp, n), jnp.float32) * 0.5
    cm = jax.random.normal(ks[2], (b, t, grp, n), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, t, h), jnp.float32))
    a_log = jax.random.normal(ks[4], (h,), jnp.float32) * 0.3
    d_skip = jnp.ones((h,), jnp.float32)
    y, state = ssd_chunked(x, bm, cm, dt, a_log, d_skip, chunk=32)
    y_ref, state_ref = ref.ssd_ref(x, bm, cm, dt, a_log, d_skip)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref, np.float32), rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(state_ref), rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("e,c,d,f", [(4, 64, 128, 256), (2, 128, 64, 128), (1, 32, 32, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm_vs_ref(e, c, d, f, dtype):
    ks = jax.random.split(RNG, 2)
    xe = jax.random.normal(ks[0], (e, c, d), jnp.float32).astype(dtype)
    w = (jax.random.normal(ks[1], (e, d, f), jnp.float32) * 0.05).astype(dtype)
    out = moe_gmm(xe, w, block_c=32, block_f=32, block_d=32, interpret=True)
    expect = ref.gmm_ref(xe, w)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(expect, np.float32), **tol(dtype))
