"""Fault-tolerant training loop: bit-exact restart, stragglers, data resume."""
import time

import jax
import jax.numpy as jnp
import pytest

from repro.checkpointing import CheckpointManager
from repro.configs import get_arch, reduced_config
from repro.configs.base import ShapeConfig
from repro.data import SyntheticTokenPipeline
from repro.models.model import build_model
from repro.optim import AdamWConfig, cosine_schedule
from repro.training import FailureInjector, TrainLoop
from repro.training.train_step import init_train_state, make_train_step

SHAPE = ShapeConfig("t", 32, 4, "train")


def build(tmpdir, cfg):
    model = build_model(cfg)
    step_fn = make_train_step(model, AdamWConfig(lr=1e-2), cosine_schedule(1e-2, 2, 20))
    state0 = init_train_state(model, jax.random.PRNGKey(0))
    make_data = lambda start: SyntheticTokenPipeline(cfg, SHAPE, seed=7, mode="affine", start_batch=start)
    return model, step_fn, state0, make_data


def test_restart_is_bit_exact(tmp_path):
    cfg = reduced_config(get_arch("llama3.2-1b"))
    _, step_fn, state0, make_data = build(tmp_path, cfg)
    loop_a = TrainLoop(step_fn, make_data, CheckpointManager(str(tmp_path / "a")), ckpt_every=4)
    state_a, hist_a = loop_a.run(state0, 12)
    loop_b = TrainLoop(step_fn, make_data, CheckpointManager(str(tmp_path / "b")), ckpt_every=4)
    injector = FailureInjector([5, 9])
    state_b, hist_b = loop_b.run(state0, 12, injector)
    assert loop_b.restarts == 2
    assert injector.fired == [5, 9]
    for a, b in zip(jax.tree.leaves(state_a["params"]), jax.tree.leaves(state_b["params"])):
        assert jnp.array_equal(a, b), "post-recovery params differ from failure-free run"


def test_training_learns_affine_stream(tmp_path):
    cfg = reduced_config(get_arch("llama3.2-1b"))
    _, step_fn, state0, make_data = build(tmp_path, cfg)
    loop = TrainLoop(step_fn, make_data, CheckpointManager(str(tmp_path / "c")), ckpt_every=0)
    _, hist = loop.run(state0, 30)
    # single-step losses are noisy on the tiny config; compare 5-step windows
    first = sum(h["loss"] for h in hist[:5]) / 5
    last = sum(h["loss"] for h in hist[-5:]) / 5
    assert last < first * 0.8, f"no learning: {first:.2f} -> {last:.2f}"


def test_straggler_detection():
    calls = {"n": 0}

    def slow_step(state, batch):
        calls["n"] += 1
        if calls["n"] == 8:
            time.sleep(1.5)  # the straggler; provlint: ok
        return state, {"loss": jnp.float32(1.0)}

    cfg = reduced_config(get_arch("llama3.2-1b"))
    make_data = lambda start: SyntheticTokenPipeline(cfg, SHAPE, seed=7, start_batch=start)
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        # jit_step=False: a jitted step would swallow the python sleep at trace time
        loop = TrainLoop(slow_step, make_data, CheckpointManager(d), ckpt_every=0,
                         straggler_factor=3.0, jit_step=False)
        state0 = {"x": jnp.zeros(())}
        loop.run(state0, 12)
    assert any(ev.step == 8 for ev in loop.straggler_events)


def test_data_pipeline_deterministic_resume():
    cfg = reduced_config(get_arch("llama3.2-1b"))
    p1 = SyntheticTokenPipeline(cfg, SHAPE, seed=3)
    batches = [next(p1) for _ in range(5)]
    p1.close()
    p2 = SyntheticTokenPipeline(cfg, SHAPE, seed=3, start_batch=3)
    resumed = next(p2)
    p2.close()
    assert jnp.array_equal(batches[3]["tokens"], resumed["tokens"])
    assert jnp.array_equal(batches[3]["targets"], resumed["targets"])


def test_affine_stream_is_next_token_predictable():
    cfg = reduced_config(get_arch("llama3.2-1b"))
    p = SyntheticTokenPipeline(cfg, SHAPE, seed=1, mode="affine")
    b = next(p)
    p.close()
    v = cfg.vocab_size
    expect = (31 * b["tokens"].astype(jnp.int64) + 7) % v
    assert jnp.array_equal(expect.astype(jnp.int32), b["targets"])


def test_microbatched_step_matches_full_batch():
    """Gradient accumulation must be loss-equivalent to the full batch."""
    import dataclasses

    cfg = reduced_config(get_arch("llama3.2-1b"))
    model_full = build_model(cfg)
    cfg_micro = dataclasses.replace(cfg, microbatches=2)
    model_micro = build_model(cfg_micro)
    state = init_train_state(model_full, jax.random.PRNGKey(0))
    step_full = make_train_step(model_full, AdamWConfig(lr=1e-2))
    step_micro = make_train_step(model_micro, AdamWConfig(lr=1e-2))
    p = SyntheticTokenPipeline(cfg, SHAPE, seed=7)
    batch = next(p)
    p.close()
    s1, m1 = jax.jit(step_full)(state, batch)
    s2, m2 = jax.jit(step_micro)(state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=2e-2)
    # grads accumulate in bf16 (see train_step.py) -> updates agree loosely
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        assert jnp.allclose(a.astype(jnp.float32), b.astype(jnp.float32), rtol=8e-2, atol=2e-2), (
            float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        )
