"""Warm provisioning: executable-index reuse, scale-to-zero park/resurrect,
and bit-exactness of restored instances on every serving path.

"Bit-exact" here always means: the restored instance runs the SAME XLA
executable (an executable-index hit, counted by ``provision_profile``) on
digest-verified restored params — so outputs are ``np.array_equal``, not
merely allclose. Fused vs UNFUSED programs are different XLA graphs and are
deliberately never compared bit-for-bit.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.dispatch import TRACER
from repro.configs import get_arch, reduced_config
from repro.core import FunctionSpec, FusionPolicy, TinyJaxBackend
from repro.launch.compile_cache import (
    EXECUTABLE_INDEX,
    ExecutableIndex,
    environment_key,
    members_digest,
    spec_digest,
)
from repro.models.model import build_model
from repro.serving.engine import ServingEngine


def _leaf(ctx, params, x):
    return jnp.tanh(x @ params["w"])


def _chain_head(ctx, params, x):
    return ctx.call("L", jnp.tanh(x @ params["w"]))


def _weights(seed, n=32):
    rs = np.random.RandomState(seed)
    return {"w": jnp.asarray(rs.randn(n, n).astype(np.float32) * 0.1)}


@pytest.fixture(autouse=True)
def _fresh_index():
    EXECUTABLE_INDEX.clear()
    yield
    EXECUTABLE_INDEX.clear()


# ------------------------------------------------------------ digest + index


def test_spec_digest_stable_and_distinguishes_params_shape():
    spec = FunctionSpec("f", _leaf, _weights(0))
    assert spec_digest(spec) == spec_digest(spec)  # memoized, deterministic
    # params are call arguments, not digest inputs: same fn = same digest
    assert spec_digest(spec) == spec_digest(FunctionSpec("f", _leaf, _weights(1)))
    assert spec_digest(spec) != spec_digest(FunctionSpec("g", _leaf, _weights(0)))


def test_spec_digest_sees_closure_values():
    """Two stages built from ONE factory share code objects and differ only
    in their closure cells — the exact aliasing a closure-blind digest would
    collide on (and then serve stage 1's executable for stage 0)."""

    def make_stage(scale):
        def fn(ctx, params, x):
            return x * scale

        return fn

    s0 = FunctionSpec("s", make_stage(2.0), {})
    s1 = FunctionSpec("s", make_stage(3.0), {})
    assert spec_digest(s0) != spec_digest(s1)


def test_members_digest_order_independent():
    a = FunctionSpec("a", _leaf, _weights(0))
    b = FunctionSpec("b", _leaf, _weights(1))
    assert members_digest({"a": a, "b": b}) == members_digest({"b": b, "a": a})


def test_environment_key_covers_dispatch_mode():
    assert len(environment_key()) == 4
    assert environment_key() == environment_key()


def test_executable_index_lru_and_counters():
    idx = ExecutableIndex(max_entries=2)
    e = dataclasses.make_dataclass("E", [("compile_s", float)])(0.5)
    idx.insert(("k1",), e)
    idx.insert(("k2",), e)
    assert idx.lookup(("k1",)) is e  # refreshes k1's recency
    idx.insert(("k3",), e)  # evicts k2, the least recently used
    assert idx.lookup(("k2",)) is None
    assert idx.lookup(("k1",)) is e
    assert idx.lookup(None) is None  # undigestable specs never hit
    s = idx.stats()
    assert s["entries"] == 2 and s["evictions"] == 1
    assert s["hits"] == 2 and s["misses"] == 1
    assert s["saved_s"] == pytest.approx(1.0)


def test_rebuilt_instance_hits_index_and_is_bit_identical():
    """The tentpole invariant: tearing a platform down and rebuilding the
    same spec reuses the compiled executable (0 recompiles) and therefore
    reproduces outputs bit-for-bit."""
    spec = FunctionSpec("f", _leaf, _weights(0))
    x = jnp.ones((4, 32), jnp.float32)

    p1 = TinyJaxBackend(FusionPolicy(enabled=False))
    try:
        p1.deploy(spec)
        r1 = np.asarray(p1.invoke("f", x))
        inst1 = p1.registry.resolve("f")
        assert inst1.provision_profile()["cache_misses"] == 1
    finally:
        p1.shutdown()

    p2 = TinyJaxBackend(FusionPolicy(enabled=False))
    try:
        p2.deploy(spec)
        base = TRACER.snapshot()
        TRACER.arm()
        try:
            r2 = np.asarray(p2.invoke("f", x))
        finally:
            TRACER.disarm()
        assert TRACER.delta(base).compiles == 0
        inst2 = p2.registry.resolve("f")
        prof = inst2.provision_profile()
        assert prof["cache_hits"] == 1 and prof["cache_misses"] == 0
        np.testing.assert_array_equal(r1, r2)
    finally:
        p2.shutdown()


def test_effectful_program_never_enters_index():
    """A program with io_callback effects closes over ITS platform — serving
    it to another platform would route async calls into a dead object. The
    index must refuse such entries."""

    def async_head(ctx, params, x):
        ctx.call_async("sink", x)
        return jnp.tanh(x @ params["w"])

    def sink(ctx, params, x):
        return x

    specs = {"hd": FunctionSpec("hd", async_head, _weights(0)),
             "sink": FunctionSpec("sink", sink, {})}
    x = jnp.ones((2, 32), jnp.float32)
    p1 = TinyJaxBackend(FusionPolicy(enabled=False))
    try:
        p1.deploy(specs["hd"])
        p1.deploy(specs["sink"])
        p1.invoke("hd", x)
    finally:
        p1.shutdown()
    # same specs on a fresh platform: if hd's effectful program had been
    # indexed, this instance would hit it — and run callbacks into p1
    p2 = TinyJaxBackend(FusionPolicy(enabled=False))
    try:
        p2.deploy(specs["hd"])
        p2.deploy(specs["sink"])
        p2.invoke("hd", x)
        prof = p2.registry.resolve("hd").provision_profile()
        assert prof["cache_hits"] == 0 and prof["cache_misses"] == 1
    finally:
        p2.shutdown()


# ---------------------------------------------------- merge/split churn


def _drive_fusion(platform, x, n=4):
    for _ in range(n):
        out = platform.invoke("H", x)
    platform.merger.wait_idle()
    return np.asarray(out)


def test_merge_split_remerge_zero_recompiles():
    """Satellite 1 + tentpole: after the first merge cycle, split and
    re-merge are both served from the executable index — churn restores,
    never rebuilds."""
    policy = FusionPolicy(min_observations=2, merge_cost_s=0.0,
                          min_group_age_s=0.0, remerge_backoff_s=0.0)
    p = TinyJaxBackend(policy)
    x = jnp.ones((4, 32), jnp.float32)
    try:
        p.deploy(FunctionSpec("H", _chain_head, _weights(0)))
        p.deploy(FunctionSpec("L", _leaf, _weights(1)))
        fused_ref = _drive_fusion(p, x)
        merges = [m for m in p.merger.merge_log if m.healthy]
        assert len(merges) == 1 and merges[0].warm is False  # cold first build

        base = TRACER.snapshot()
        TRACER.arm()
        try:
            ev = p.merger.split(frozenset({"H", "L"}), [{"H"}, {"L"}])
            assert ev is not None and ev.healthy and ev.warm
            fused_again = _drive_fusion(p, x)
        finally:
            TRACER.disarm()
        assert TRACER.delta(base).compiles == 0
        merges = [m for m in p.merger.merge_log if m.healthy]
        assert len(merges) == 2 and merges[1].warm is True
        # same executable, same params -> bit-identical fused outputs
        np.testing.assert_array_equal(fused_ref, fused_again)
        stats = p.stats()["provisioning"]
        assert stats["counts"]["merge"] == 2 and stats["counts"]["split"] == 1
        assert stats["compile_cache"]["hits"] > 0
    finally:
        p.shutdown()


# ------------------------------------------------------ park + resurrect


def test_scale_to_zero_resurrect_bit_identical_and_billed(tmp_path):
    p = TinyJaxBackend(FusionPolicy(enabled=False), snapshot_dir=str(tmp_path))
    x = jnp.ones((4, 32), jnp.float32)
    try:
        p.deploy(FunctionSpec("f", _leaf, _weights(0)))
        ref = np.asarray(p.invoke("f", x))
        parked = p.scale_to_zero("f")
        assert parked == ("f",)
        assert p.provisioning_stats()["parked"] == ["f"]
        assert p.registry.get("f") is None  # route is gone, RAM released
        assert p.snapshots.stats()["puts"] == 1

        base = TRACER.snapshot()
        TRACER.arm()
        try:
            got = np.asarray(p.invoke("f", x))
        finally:
            TRACER.disarm()
        assert TRACER.delta(base).compiles == 0
        np.testing.assert_array_equal(ref, got)
        assert p.provisioning_stats()["parked"] == []

        prov = p.meter.summary()["provisioning"]
        # resurrect time is billed; the parked idle time is not a record at all
        assert prov["billed_s"] > 0.0
        kinds = [r.kind for r in p.meter.provisioning]
        assert kinds.count("park") == 1 and kinds.count("resurrect") == 1
        billed = {r.kind: r.billed for r in p.meter.provisioning}
        assert billed["resurrect"] is True and billed["park"] is False
        rez = [r for r in p.meter.provisioning if r.kind == "resurrect"][0]
        assert rez.warm is True  # executable came from the index
    finally:
        p.shutdown()


def test_invocation_billing_unchanged_by_provisioning(tmp_path):
    """Provisioning is a separate line item: total_gb_s must cover exactly
    the invocation records, with or without parks in the session."""
    p = TinyJaxBackend(FusionPolicy(enabled=False), snapshot_dir=str(tmp_path))
    x = jnp.ones((4, 32), jnp.float32)
    try:
        p.deploy(FunctionSpec("f", _leaf, _weights(0)))
        p.invoke("f", x)
        p.scale_to_zero("f")
        p.invoke("f", x)
        s = p.meter.summary()
        with p.meter._lock:
            invocation_total = sum(r.gb_seconds for r in p.meter.records)
        assert s["total_gb_s"] == pytest.approx(invocation_total)
    finally:
        p.shutdown()


def test_resurrect_of_fused_group_re_fuses_bit_identical(tmp_path):
    """Round trip: merge -> park the fused unit -> resurrect -> re-merge.
    The re-fused unit must reuse the first fused executable (index hit) and
    reproduce fused outputs bit-for-bit."""
    policy = FusionPolicy(min_observations=2, merge_cost_s=0.0,
                          min_group_age_s=0.0, remerge_backoff_s=0.0)
    p = TinyJaxBackend(policy, snapshot_dir=str(tmp_path))
    x = jnp.ones((4, 32), jnp.float32)
    try:
        p.deploy(FunctionSpec("H", _chain_head, _weights(0)))
        p.deploy(FunctionSpec("L", _leaf, _weights(1)))
        fused_ref = _drive_fusion(p, x)
        assert any(m.healthy for m in p.merger.merge_log)

        parked = p.scale_to_zero("H")  # parks the whole fused {H, L} unit
        assert set(parked) == {"H", "L"}
        assert set(p.provisioning_stats()["parked"]) == {"H", "L"}

        fused_again = _drive_fusion(p, x)  # resurrect singletons, re-fuse
        merges = [m for m in p.merger.merge_log if m.healthy]
        assert len(merges) == 2 and merges[1].warm is True
        np.testing.assert_array_equal(fused_ref, fused_again)
        counts = p.provisioning_stats()["counts"]
        assert counts["park"] == 1 and counts["resurrect"] >= 1
    finally:
        p.shutdown()


def test_idle_park_tick_parks_and_invoke_resurrects(tmp_path):
    """Scale-to-zero end to end on the reconciler path: an idle function is
    parked by the tick hook, and the next invoke transparently resurrects."""
    from repro.scheduler.clock import VirtualClock

    clock = VirtualClock()
    p = TinyJaxBackend(FusionPolicy(enabled=False), snapshot_dir=str(tmp_path),
                       idle_park_s=5.0, clock=clock)
    x = jnp.ones((4, 32), jnp.float32)
    try:
        p.deploy(FunctionSpec("f", _leaf, _weights(0)))
        ref = np.asarray(p.invoke("f", x))
        clock.advance(10.0)
        p._idle_park_tick()
        assert p.provisioning_stats()["parked"] == ["f"]
        got = np.asarray(p.invoke("f", x))
        np.testing.assert_array_equal(ref, got)
        assert p.provisioning_stats()["parked"] == []
    finally:
        p.shutdown()


# -------------------------------------------------- serving-path bit-exact


def _engine(tmp_path, *, kv_pages=0, fused=False):
    cfg = reduced_config(get_arch("llama3.2-1b"))
    model = build_model(cfg)
    platform = TinyJaxBackend(
        FusionPolicy(min_observations=2, merge_cost_s=0.0, enabled=fused),
        snapshot_dir=str(tmp_path),
    )
    engine = ServingEngine(model, platform, max_len=48,
                           kv_pages=kv_pages, kv_page_size=16)
    return engine, platform


def test_dense_and_paged_chains_resurrect_bit_identical(tmp_path):
    """One engine (with a KV arena), two serving paths: plain dense decode
    and paged decode must BOTH reproduce outputs bit-for-bit after a full
    park -> resurrect cycle."""
    engine, platform = _engine(tmp_path, kv_pages=32)
    tokens = jax.random.randint(jax.random.PRNGKey(0), (2, 8), 0,
                                engine.cfg.vocab_size, jnp.int32)
    try:
        ref, _ = engine.generate({"tokens": tokens}, steps=6)
        parked = engine.scale_to_zero()
        assert set(parked) == set(engine.chain_names())
        got, _ = engine.generate({"tokens": tokens}, steps=6)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))

        ref_p, _ = engine.generate_paged({"tokens": tokens[:1]}, steps=6)
        assert engine.scale_to_zero()
        got_p, _ = engine.generate_paged({"tokens": tokens[:1]}, steps=6)
        np.testing.assert_array_equal(np.asarray(ref_p), np.asarray(got_p))
    finally:
        platform.shutdown()


def test_fused_chain_resurrects_bit_identical(tmp_path):
    engine, platform = _engine(tmp_path, fused=True)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                engine.cfg.vocab_size, jnp.int32)
    try:
        ref, _ = engine.generate({"tokens": tokens}, steps=8)
        platform.merger.wait_idle()
        assert any(m.healthy for m in platform.merger.merge_log)
        # a second pass on the settled (fused) chain is the reference
        ref, _ = engine.generate({"tokens": tokens}, steps=8)
        parked = engine.scale_to_zero()
        assert parked
        # resurrect + let the chain re-fuse, then compare the settled outputs
        engine.generate({"tokens": tokens}, steps=8)
        platform.merger.wait_idle()
        got, _ = engine.generate({"tokens": tokens}, steps=8)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
    finally:
        platform.shutdown()


