"""Sharding-rule unit + property tests (no multi-device mesh needed: rules
are pure functions of axis sizes). Property cases enumerate the full kv_heads
domain directly instead of sampling it via the optional `hypothesis` package."""
import pytest

from repro.sharding.specs import LogicalRules


def rules_16x16(extra=None):
    base = {
        "batch": ("data",),
        "seq": "model",
        "heads": "model",
        "kv_heads": "model",
        "embed_fsdp": ("data",),
        "vocab": "model",
    }
    if extra:
        base.update(extra)
    return LogicalRules(base, {"data": 16, "model": 16})


def test_strict_drops_uneven_axes():
    r = rules_16x16()
    # vocab 50280 not divisible by 16 -> dropped under strict
    assert r.spec_entry("vocab", 50280, strict=True) is None
    assert r.spec_entry("vocab", 151936, strict=True) == "model"
    # lenient path keeps it (constraint padding)
    assert r.spec_entry("vocab", 50280, strict=False) == "model"


def test_heads_uneven_dropped_strict():
    r = rules_16x16()
    assert r.spec_entry("heads", 24, strict=True) is None   # starcoder2
    assert r.spec_entry("heads", 32, strict=True) == "model"


def test_no_duplicate_mesh_axes_in_spec():
    from repro.sharding.specs import to_pspec

    r = rules_16x16({"a": "model", "b": "model"})
    spec = to_pspec((32, 32), ("a", "b"), r)
    flat = [ax for e in spec if e for ax in ((e,) if isinstance(e, str) else e)]
    assert len(flat) == len(set(flat))


@pytest.mark.parametrize("kv", [1, 2, 4, 8, 16, 32, 64])
def test_cache_rules_always_shard_somewhere(kv):
    """Property: for every kv_heads count, the decode cache gets sharded on
    heads or sequence — never left fully replicated."""
    from repro.sharding.specs import _cache_rules

    sizes = {"data": 16, "model": 16}
    rules = _cache_rules(sizes, kv)
    r = LogicalRules({**rules}, sizes)
    head_entry = r.spec_entry("cache_kv_heads", kv, strict=True)
    seq_entry = r.spec_entry("cache_seq", 32768, strict=True)
    assert head_entry is not None or seq_entry is not None
    # heads shard exactly when divisible by the TP axis
    assert (head_entry == "model") == (kv % 16 == 0 and kv >= 16)


def test_rules_fsdp_policy():
    """Train + prefill keep FSDP params; decode is TP-only (latency)."""
    import os

    from repro.sharding.specs import decode_rules, infer_rules, train_rules
    from repro.launch.mesh import make_smoke_mesh

    mesh = make_smoke_mesh()
    assert train_rules(mesh).rules["embed_fsdp"] is not None
    assert infer_rules(mesh).rules["embed_fsdp"] is not None
    assert decode_rules(mesh, kv_heads=8, batch=128).rules["embed_fsdp"] is None
