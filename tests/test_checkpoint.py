"""Checkpoint manager: atomicity, bf16 round-trip, retention, async save —
plus the snapshot store backing scale-to-zero provisioning."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing import (
    CheckpointManager,
    CheckpointSaveError,
    SnapshotIntegrityError,
    SnapshotStore,
    snapshot_digest,
)


def make_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {
            "w": jax.random.normal(k, (8, 16)).astype(jnp.bfloat16),
            "b": jnp.arange(16, dtype=jnp.float32),
        },
        "opt": {"step": jnp.int32(7), "m": jnp.ones((8, 16), jnp.float32)},
    }


def assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert x.dtype == y.dtype


def test_roundtrip_exact_including_bf16(tmp_path):
    m = CheckpointManager(str(tmp_path))
    state = make_state()
    m.save(3, state)
    restored = m.restore(state, 3)
    assert_tree_equal(state, restored)


def test_latest_and_retention(tmp_path):
    m = CheckpointManager(str(tmp_path), retain=2)
    state = make_state()
    for s in (1, 2, 3, 4):
        m.save(s, state)
    assert m.latest_step() == 4
    assert m.all_steps() == [3, 4]  # older ones pruned


def test_no_tmp_dirs_left_behind(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(1, make_state())
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_restore_missing_raises(tmp_path):
    m = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        m.restore(make_state())


def test_async_save_then_restore(tmp_path):
    m = CheckpointManager(str(tmp_path), async_save=True)
    state = make_state()
    m.save(10, state)
    m.wait()
    assert m.latest_step() == 10
    assert_tree_equal(state, m.restore(state, 10))


def test_restore_into_structs(tmp_path):
    """Elastic restore: the 'like' tree can be ShapeDtypeStructs (a fresh job
    that never materialized params restores straight from disk)."""
    m = CheckpointManager(str(tmp_path))
    state = make_state()
    m.save(2, state)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored = m.restore(like, 2)
    assert_tree_equal(state, restored)


# --------------------------------------------------------- async save errors


def _failing_writer(path, **arrays):
    raise OSError("disk full (injected)")


def test_async_save_failure_surfaces_on_wait(tmp_path):
    """A worker-thread save failure must not vanish: wait() raises it."""
    m = CheckpointManager(str(tmp_path), async_save=True, writer=_failing_writer)
    m.save(1, make_state())
    with pytest.raises(CheckpointSaveError, match="disk full"):
        m.wait()
    # surfaced once: the caller was told, the manager is usable again
    m.wait()


def test_async_save_failure_surfaces_on_latest_step(tmp_path):
    """A loop that never calls wait() still hears about the dead save the
    moment it asks which step is current — the failed step must not let an
    older checkpoint masquerade as latest."""
    m = CheckpointManager(str(tmp_path), async_save=True, writer=_failing_writer)
    m.save(5, make_state())
    m._save_thread.join()  # let the worker die without consuming the error
    with pytest.raises(CheckpointSaveError):
        m.latest_step()


def test_async_save_failure_then_next_save_succeeds(tmp_path):
    """Transient failure: the next save() surfaces the old error, and a
    recovered writer persists normally afterwards."""
    m = CheckpointManager(str(tmp_path), async_save=True, writer=_failing_writer)
    state = make_state()
    m.save(1, state)
    m._save_thread.join()
    m._writer = np.savez  # the disk came back
    with pytest.raises(CheckpointSaveError):
        m.save(2, state)  # surfaces step 1's failure...
    m.save(2, state)  # ...and the retry goes through
    m.wait()
    assert m.latest_step() == 2
    assert_tree_equal(state, m.restore(state, 2))


def test_sync_save_failure_raises_inline(tmp_path):
    """Synchronous saves keep raising at the call site, not via wait()."""
    m = CheckpointManager(str(tmp_path), writer=_failing_writer)
    with pytest.raises(OSError, match="disk full"):
        m.save(1, make_state())


# ------------------------------------------------------------ snapshot store


def test_snapshot_roundtrip_bit_exact_including_bf16(tmp_path):
    store = SnapshotStore(str(tmp_path))
    state = make_state()
    digest = store.put(state)
    assert store.contains(digest)
    restored = store.restore(digest, state)
    assert_tree_equal(state, restored)
    # content address is a function of the bytes: restored re-hashes to it
    assert snapshot_digest(jax.tree.map(np.asarray, restored)) == digest


def test_snapshot_restore_into_structs(tmp_path):
    """Resurrect path: the parked spec keeps only ShapeDtypeStructs."""
    store = SnapshotStore(str(tmp_path))
    state = make_state()
    digest = store.put(state)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    assert_tree_equal(state, store.restore(digest, like))


def test_snapshot_put_dedups_identical_content(tmp_path):
    store = SnapshotStore(str(tmp_path))
    d1 = store.put(make_state(seed=3))
    d2 = store.put(make_state(seed=3))  # same bytes, fresh tree
    assert d1 == d2
    assert store.stats()["puts"] == 1
    assert store.stats()["dedup_hits"] == 1
    assert store.stats()["entries"] == 1


def test_snapshot_distinct_content_distinct_digests(tmp_path):
    store = SnapshotStore(str(tmp_path))
    assert store.put(make_state(seed=0)) != store.put(make_state(seed=1))
    assert store.stats()["entries"] == 2


def test_snapshot_retention_evicts_lru(tmp_path):
    store = SnapshotStore(str(tmp_path), retain=2)
    digests = [store.put(make_state(seed=s)) for s in range(4)]
    # os.utime granularity can tie mtimes on fast filesystems; eviction keeps
    # exactly `retain` entries either way
    assert store.stats()["entries"] == 2
    assert store.stats()["evicted"] == 2
    assert store.contains(digests[-1])


def test_snapshot_corruption_detected(tmp_path):
    store = SnapshotStore(str(tmp_path))
    state = make_state()
    digest = store.put(state)
    # flip bytes in one stored leaf
    leaf = os.path.join(store.path_of(digest), "leaf_00000.npy")
    raw = bytearray(open(leaf, "rb").read())
    raw[-4] ^= 0xFF
    open(leaf, "wb").write(bytes(raw))
    with pytest.raises(SnapshotIntegrityError):
        store.restore(digest, state)
    # verify=False is the caller's explicit opt-out
    store.restore(digest, state, verify=False)


def test_snapshot_missing_digest_raises(tmp_path):
    store = SnapshotStore(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        store.restore("0" * 32, make_state())
