"""Checkpoint manager: atomicity, bf16 round-trip, retention, async save."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing import CheckpointManager


def make_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {
            "w": jax.random.normal(k, (8, 16)).astype(jnp.bfloat16),
            "b": jnp.arange(16, dtype=jnp.float32),
        },
        "opt": {"step": jnp.int32(7), "m": jnp.ones((8, 16), jnp.float32)},
    }


def assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert x.dtype == y.dtype


def test_roundtrip_exact_including_bf16(tmp_path):
    m = CheckpointManager(str(tmp_path))
    state = make_state()
    m.save(3, state)
    restored = m.restore(state, 3)
    assert_tree_equal(state, restored)


def test_latest_and_retention(tmp_path):
    m = CheckpointManager(str(tmp_path), retain=2)
    state = make_state()
    for s in (1, 2, 3, 4):
        m.save(s, state)
    assert m.latest_step() == 4
    assert m.all_steps() == [3, 4]  # older ones pruned


def test_no_tmp_dirs_left_behind(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(1, make_state())
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_restore_missing_raises(tmp_path):
    m = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        m.restore(make_state())


def test_async_save_then_restore(tmp_path):
    m = CheckpointManager(str(tmp_path), async_save=True)
    state = make_state()
    m.save(10, state)
    m.wait()
    assert m.latest_step() == 10
    assert_tree_equal(state, m.restore(state, 10))


def test_restore_into_structs(tmp_path):
    """Elastic restore: the 'like' tree can be ShapeDtypeStructs (a fresh job
    that never materialized params restores straight from disk)."""
    m = CheckpointManager(str(tmp_path))
    state = make_state()
    m.save(2, state)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored = m.restore(like, 2)
    assert_tree_equal(state, restored)
